//! Fault-injection engine integration tests (robustness tentpole).
//!
//! What is pinned:
//! * **Off == today**: an enabled-but-neutral `FaultPlan` (no injector
//!   can fire) is bit-identical to the default-off configuration, for
//!   every protocol × churn model × fabric setting — the engine routes
//!   on `any_injector()`, so arming the policy knobs alone must not
//!   perturb a single bit.
//! * **Width invariance**: the `chaos` preset (every injector live on
//!   the contended fabric) is bit-identical across thread widths
//!   {1, 3, 8} — injector queries are pure in (round, client).
//! * **Mid-download crash reschedules contention**: a client cut while
//!   its sync copy is on the FIFO server stream frees the stream at the
//!   cut, so survivors' queue waits shrink — never grow.
//! * **Bounded retry**: a flap that cuts a trailing upload leg is
//!   salvaged by the server's retry-with-backoff when the budget allows
//!   it, and counts as an upload crash when `retry_max = 0`; backoff
//!   doubles per attempt and saturates at the cap.
//! * **Partial-progress credit**: a crashed continuation job resumes
//!   from the work it finished, not from zero, iff `partial_credit`.

use safa::client::ClientState;
use safa::config::{presets, ChurnModel, ExperimentConfig, ProtocolKind};
use safa::engine::{AvailabilityModel, FleetEngine, RoundCtx};
use safa::faults::{FaultPlan, FaultRuntime};
use safa::model::ParamVec;
use safa::net::fabric::{FabricConfig, FabricRuntime};
use safa::net::NetworkModel;
use safa::protocol::{make_protocol, FedEnv};
use safa::sim::ContinuationSim;
use safa::util::parallel::with_thread_count;
use safa::util::rng::Pcg64;

const WIDTHS: [usize; 3] = [1, 3, 8];
const PROTOS: [ProtocolKind; 4] = [
    ProtocolKind::Safa,
    ProtocolKind::FedAvg,
    ProtocolKind::FedCs,
    ProtocolKind::FedAsync,
];

fn churns() -> [ChurnModel; 2] {
    [
        ChurnModel::Bernoulli,
        ChurnModel::Markov {
            mean_uptime_s: 300.0,
            mean_downtime_s: 200.0,
        },
    ]
}

fn contended_fabric() -> FabricConfig {
    FabricConfig::from_parts(
        "fifo",
        None,
        Some("lognormal"),
        Some(0.5),
        Some(0.05),
        Some(0.02),
        Some(0.02),
        None,
        None,
        None,
        None,
    )
    .unwrap()
}

/// Per-round fingerprint: every field that could diverge, on raw bits.
type Fingerprint = (u64, usize, usize, usize, u64, u64, Vec<u32>, u32);

fn run_fingerprints(cfg: &ExperimentConfig, rounds: usize) -> Vec<Fingerprint> {
    let mut env = FedEnv::new(cfg).unwrap();
    let mut proto = make_protocol(&env);
    (1..=rounds)
        .map(|t| {
            let rec = proto.run_round(t, &mut env);
            (
                rec.round_len.to_bits(),
                rec.n_picked,
                rec.n_picked_crashed,
                rec.n_committed,
                rec.bytes_down.to_bits(),
                rec.bytes_up.to_bits(),
                rec.staleness.clone(),
                proto.global().as_slice()[0].to_bits(),
            )
        })
        .collect()
}

/// Off == today: an enabled plan with no live injector takes the
/// legacy paths bit-for-bit, for every protocol × churn × fabric cell.
#[test]
fn neutral_plan_is_bit_identical_to_faults_off() {
    for kind in PROTOS {
        for churn in churns() {
            for fabric_on in [false, true] {
                let mut cfg = presets::preset("tiny").unwrap();
                cfg.protocol.kind = kind;
                cfg.env.crash_prob = 0.3;
                cfg.env.churn = churn.clone();
                cfg.seed = 11;
                if fabric_on {
                    cfg.env.fabric = contended_fabric();
                }
                let off = run_fingerprints(&cfg, 5);
                // Arm the master switch and every *policy* knob, but no
                // injector: the run must not change in a single bit.
                cfg.env.faults = FaultPlan {
                    enabled: true,
                    retry_max: 7,
                    retry_backoff_s: 3.0,
                    retry_backoff_cap_s: 11.0,
                    partial_credit: false,
                    ..FaultPlan::default()
                };
                assert!(!cfg.env.faults.any_injector());
                let neutral = run_fingerprints(&cfg, 5);
                assert_eq!(
                    off, neutral,
                    "{}/{churn:?}/fabric={fabric_on}: neutral plan diverged",
                    kind.name()
                );
            }
        }
    }
}

/// The chaos preset — every injector live on the contended fabric — is
/// bit-identical at widths {1, 3, 8} for fresh-round and continuation
/// protocols alike.
#[test]
fn chaos_runs_are_width_invariant() {
    for kind in [
        ProtocolKind::Safa,
        ProtocolKind::FedAvg,
        ProtocolKind::FedAsync,
    ] {
        let mut cfg = presets::preset("chaos").unwrap();
        cfg.protocol.kind = kind;
        cfg.env.m = 120; // enough participants that widths genuinely fork
        cfg.task.n = 1200;
        cfg.task.n_test = 60;
        cfg.train.rounds = 4;
        assert!(cfg.env.faults.enabled && cfg.env.faults.any_injector());
        let reference = with_thread_count(1, || run_fingerprints(&cfg, cfg.train.rounds));
        for &width in &WIDTHS[1..] {
            let got = with_thread_count(width, || run_fingerprints(&cfg, cfg.train.rounds));
            assert_eq!(
                got,
                reference,
                "{} chaos run diverged at width {width}",
                kind.name()
            );
        }
    }
}

/// A deterministic synthetic fleet with fast training, so round timing
/// is dominated by the transfer legs under test.
fn fast_fleet(m: usize) -> Vec<ClientState> {
    (0..m)
        .map(|id| ClientState {
            id,
            perf: 50.0,
            batches_per_epoch: 1,
            n_k: 10,
            local_model: ParamVec::zeros(1),
            version: 0,
            base_version: 0,
            committed_last: true,
            picked_last: false,
            pending_partial: 0.0,
            job: None,
        })
        .collect()
}

/// Mid-download crash semantics on the contended fabric: a client cut
/// while (or before) its copy is on the single FIFO server stream frees
/// the stream early, so every surviving arrival lands no later than in
/// the injector-free run — and strictly earlier whenever a queued copy
/// ahead of it was cancelled mid-push.
#[test]
fn mid_download_crash_shrinks_survivor_waits() {
    let m = 24;
    let mut cfg = presets::preset("tiny").unwrap();
    cfg.env.m = m;
    cfg.env.crash_prob = 0.0; // injector cuts are the only failures
    cfg.env.fabric = FabricConfig::from_parts(
        "fifo", None, None, None, None, None, None, None, None, None, None,
    )
    .unwrap();
    cfg.env.faults = FaultPlan {
        enabled: true,
        crash_hazard: 0.9,
        ..FaultPlan::default()
    };
    let fabric = FabricRuntime::new(&cfg.env, cfg.seed);
    let (streams, service) = fabric.contention_slots();
    assert_eq!(streams, 1, "FIFO fabric must serialize the server link");
    // Deadline sized to the round's actual activity span (queue drain +
    // one download + one upload + slack), so injector cuts — uniform
    // over the horizon — usually land while transfers are in flight.
    let td = fabric.t_down(1, 0);
    cfg.train.t_lim = (m as f64 * service + 2.0 * td) * 1.2;
    let fr = FaultRuntime::new(&cfg);
    let net = NetworkModel::new(&cfg.env);
    let clients = fast_fleet(m);
    let participants: Vec<usize> = (0..m).collect();
    let synced = vec![true; m];

    let avail = AvailabilityModel::BernoulliPerRound { crash_prob: 0.0 };
    let mut legacy = FleetEngine::new(avail.clone(), m);
    let mut faulty = FleetEngine::new(avail, m);
    let mut arrivals_l = vec![f64::NAN; m];
    let mut strictly_earlier = 0usize;
    let mut cuts = 0usize;
    for t in 1..=40 {
        let rng = Pcg64::new(0xd1).split(t as u64);
        let base = legacy.run_round(
            t,
            RoundCtx {
                cfg: &cfg,
                net: &net,
                clients: &clients,
                fabric: Some(&fabric),
                faults: None,
            },
            &participants,
            &synced,
            &rng,
        );
        assert_eq!(base.arrivals.len(), m, "t={t}: injector-free baseline drops");
        arrivals_l.fill(f64::NAN);
        for a in &base.arrivals {
            arrivals_l[a.client] = a.time;
        }
        let sim = faulty.run_round(
            t,
            RoundCtx {
                cfg: &cfg,
                net: &net,
                clients: &clients,
                fabric: Some(&fabric),
                faults: Some(&fr),
            },
            &participants,
            &synced,
            &rng,
        );
        cuts += sim.failures.len();
        for a in &sim.arrivals {
            let before = arrivals_l[a.client];
            assert!(
                a.time <= before + 1e-9,
                "t={t}: survivor {} arrived LATER under faults ({} > {before})",
                a.client,
                a.time
            );
            if a.time < before - 1e-9 {
                strictly_earlier += 1;
            }
        }
    }
    assert!(cuts > 0, "crash injector never fired over 40 rounds");
    assert!(
        strictly_earlier > 0,
        "no survivor's queue wait ever shrank — mid-download cancellation \
         did not free the contended stream ({cuts} cuts observed)"
    );
}

/// Bounded retry on a flap-cut upload leg: with budget the server
/// replays the tail after a capped backoff and the update still lands;
/// with `retry_max = 0` the same cut counts as an upload crash.
#[test]
fn retry_budget_salvages_flapped_uploads() {
    let m = 60;
    let job = 200.0;
    let mk = |retry_max: u32| -> (ExperimentConfig, FaultRuntime) {
        let mut cfg = presets::preset("tiny").unwrap();
        cfg.env.m = m;
        cfg.env.crash_prob = 0.0;
        cfg.train.t_lim = 1000.0;
        cfg.env.faults = FaultPlan {
            enabled: true,
            crash_hazard: 1.0, // every client draws a cut somewhere
            flap_prob: 1.0,    // ... and every cut recovers
            flap_downtime_s: 1.0,
            retry_max,
            retry_backoff_s: 5.0,
            retry_backoff_cap_s: 60.0,
            ..FaultPlan::default()
        };
        let fr = FaultRuntime::new(&cfg);
        (cfg, fr)
    };
    let participants: Vec<usize> = (0..m).collect();
    let jobs = vec![job; m];
    // The whole job is its upload tail: any cut that lands before the
    // job completes is a mid-upload cancellation.
    let tails = vec![job; m];
    let run = |retry_max: u32| -> ContinuationSim {
        let (cfg, fr) = mk(retry_max);
        let mut engine = FleetEngine::new(
            AvailabilityModel::BernoulliPerRound { crash_prob: 0.0 },
            m,
        );
        let mut out = ContinuationSim::default();
        let rng = Pcg64::new(0xab).split(1);
        engine.run_continuation_faults_into(
            1,
            &cfg,
            &participants,
            &jobs,
            &tails,
            None,
            &fr,
            &rng,
            &mut out,
        );
        out
    };
    let no_retry = run(0);
    let with_retry = run(2);
    assert!(
        no_retry.upload_crashed > 0,
        "no upload-leg cut fired — the scenario lost its teeth"
    );
    assert_eq!(
        with_retry.upload_crashed, 0,
        "budgeted retries should salvage every flapped upload"
    );
    assert!(
        with_retry.arrivals.len() > no_retry.arrivals.len(),
        "retries must convert upload crashes back into arrivals \
         ({} vs {})",
        with_retry.arrivals.len(),
        no_retry.arrivals.len()
    );
    // A retried tail lands at cut + backoff + tail: visibly after the
    // un-cut completion time, never past the deadline.
    assert!(
        with_retry.arrivals.iter().any(|a| a.time > job + 4.9),
        "no arrival shows the retry backoff + replayed tail"
    );
    assert!(with_retry.arrivals.iter().all(|a| a.time <= 1000.0));

    // Backoff doubles per attempt and saturates at the cap.
    let (_, fr) = mk(2);
    assert_eq!(fr.backoff(1), 5.0);
    assert_eq!(fr.backoff(2), 10.0);
    assert_eq!(fr.backoff(3), 20.0);
    assert_eq!(fr.backoff(5), 60.0, "backoff must cap, not overflow");
    assert_eq!(fr.backoff(63), 60.0);
}

/// Partial-progress credit: after a crash round, a cut client's paused
/// job carries `remaining - done` iff the policy is on — identical cuts
/// (same seed) with the policy off resume from the full remaining work.
#[test]
fn partial_credit_resumes_interrupted_jobs_from_the_cut() {
    let remaining = |credit: bool| -> Vec<Option<f64>> {
        let mut cfg = presets::preset("tiny").unwrap();
        cfg.protocol.kind = ProtocolKind::FedAsync;
        cfg.env.m = 40;
        cfg.task.n = 400;
        cfg.task.n_test = 40;
        cfg.env.crash_prob = 0.0;
        cfg.seed = 5;
        // Tight deadline + certain cut draw: most jobs (~120 s of
        // transfer + training) are still in flight when their uniform
        // [0, T_lim) cut lands, so plenty of jobs pause mid-flight.
        cfg.train.t_lim = 200.0;
        cfg.env.faults = FaultPlan {
            enabled: true,
            crash_hazard: 1.0, // hard crashes: no flap, no retry
            partial_credit: credit,
            ..FaultPlan::default()
        };
        let mut env = FedEnv::new(&cfg).unwrap();
        let mut proto = make_protocol(&env);
        let _ = proto.run_round(1, &mut env);
        env.clients.iter().map(|c| c.job.map(|j| j.remaining)).collect()
    };
    let credited = remaining(true);
    let flat = remaining(false);
    assert_eq!(credited.len(), flat.len());
    let mut strictly_less = 0usize;
    let mut paused = 0usize;
    for (k, (a, b)) in credited.iter().zip(&flat).enumerate() {
        match (a, b) {
            (Some(a), Some(b)) => {
                paused += 1;
                assert!(
                    a <= &(b + 1e-9),
                    "client {k}: credit increased remaining work ({a} > {b})"
                );
                if *a < b - 1e-9 {
                    strictly_less += 1;
                }
            }
            // Same seed, same cuts: the paused set must be identical.
            (a, b) => assert_eq!(a, b, "client {k}: paused sets diverged"),
        }
    }
    assert!(paused > 0, "no job was ever interrupted — hazard dead?");
    assert!(
        strictly_less > 0,
        "partial credit never reduced a paused job's remaining work \
         ({paused} paused jobs)"
    );
}
