//! Communication-cost accounting invariants (fabric satellite).
//!
//! The comm books must track what the simulated network actually moved,
//! independent of protocol and churn model:
//!
//! * downlink: exactly one (possibly compressed) model copy per synced /
//!   freshly-pulled client — `bytes_down == m_sync × payload_bytes`;
//! * uplink: uploads are counted only for updates that actually arrived
//!   at the server this round — `bytes_up == n_committed ×
//!   payload_bytes` — never for picked-but-crashed clients;
//! * with no codec, `payload_bytes == model_bytes` and `bytes_saved ==
//!   0`; with a codec, the identity `bytes_moved + bytes_saved ==
//!   uncompressed bytes_moved` holds per round.
//!
//! Checked for SAFA, FedAvg, and FedAsync under Bernoulli crashes and
//! Markov churn, with the fabric off and with a quantizing fabric on.

use safa::config::{presets, ChurnModel, ExperimentConfig, ProtocolKind};
use safa::net::fabric::FabricConfig;
use safa::protocol::{make_protocol, FedEnv};

const PROTOS: [ProtocolKind; 3] = [
    ProtocolKind::Safa,
    ProtocolKind::FedAvg,
    ProtocolKind::FedAsync,
];

fn churns() -> [ChurnModel; 2] {
    [
        ChurnModel::Bernoulli,
        ChurnModel::Markov {
            mean_uptime_s: 300.0,
            mean_downtime_s: 200.0,
        },
    ]
}

fn cfg_for(kind: ProtocolKind, churn: ChurnModel) -> ExperimentConfig {
    let mut cfg = presets::preset("tiny").unwrap();
    cfg.protocol.kind = kind;
    cfg.env.crash_prob = 0.3; // plenty of picked-but-crashed clients
    cfg.env.churn = churn;
    cfg.seed = 23;
    cfg
}

/// Drive `rounds` rounds asserting the byte invariants with the given
/// payload ratio (1.0 when no codec is configured).
fn assert_books(cfg: &ExperimentConfig, ratio: f64, rounds: usize) {
    let mut env = FedEnv::new(cfg).unwrap();
    let model_bytes = env.net.model_bytes;
    let payload = model_bytes * ratio;
    let mut proto = make_protocol(&env);
    let mut saw_crash = false;
    for t in 1..=rounds {
        let rec = proto.run_round(t, &mut env);
        let label = format!("{}/{:?} t={t}", cfg.protocol.kind.name(), cfg.env.churn);
        assert!(
            (rec.bytes_down - rec.m_sync as f64 * payload).abs() < 1e-6,
            "{label}: bytes_down {} != m_sync {} × payload {payload}",
            rec.bytes_down,
            rec.m_sync
        );
        assert!(
            (rec.bytes_up - rec.n_committed as f64 * payload).abs() < 1e-6,
            "{label}: bytes_up {} != n_committed {} × payload {payload}",
            rec.bytes_up,
            rec.n_committed
        );
        // Uploads only for arrivals: crashed/offline clients moved no
        // uplink bytes this round (SAFA counts every arrival — picked
        // plus undrafted bypass — as an upload; FedAvg only the picked
        // clients that survived to completion).
        if rec.n_crashed > 0 {
            saw_crash = true;
        }
        let uncompressed = (rec.m_sync + rec.n_committed) as f64 * model_bytes;
        assert!(
            (rec.bytes_down + rec.bytes_up + rec.bytes_saved - uncompressed).abs() < 1e-6,
            "{label}: moved + saved != uncompressed total"
        );
        if ratio >= 1.0 {
            assert_eq!(
                rec.bytes_saved.to_bits(),
                0.0f64.to_bits(),
                "{label}: bytes_saved nonzero without a codec"
            );
        }
    }
    // The invariant is only interesting if some client actually dropped
    // out: demand the crash/offline branch was exercised at least once.
    assert!(
        saw_crash,
        "{}/{:?}: no client ever crashed over {rounds} rounds — \
         the uploads-only-for-arrivals branch went unexercised",
        cfg.protocol.kind.name(),
        cfg.env.churn
    );
}

#[test]
fn books_match_traffic_without_codec() {
    for kind in PROTOS {
        for churn in churns() {
            assert_books(&cfg_for(kind, churn), 1.0, 8);
        }
    }
}

/// Fault-injection satellite: with injectors live on a lossy fabric the
/// flat `m_sync × payload` / `n_committed × payload` identities become
/// *floors* — retried server copies and loss retransmits re-send whole
/// payloads, so the books may only exceed the floor by a non-negative
/// integer multiple of the payload. (With faults off the exact
/// identities above keep holding bit-for-bit; that path is pinned by
/// `books_match_traffic_without_codec` and tests/faults.rs.)
#[test]
fn retransmits_book_whole_payloads_under_faults() {
    use safa::faults::FaultPlan;

    let fabric = FabricConfig::from_parts(
        "fifo",
        None,
        None,
        None,
        Some(0.05),
        Some(0.02),
        Some(0.15), // lossy: plenty of per-leg retransmits
        None,
        None,
        None,
        None,
    )
    .unwrap();
    let mut saw_excess = false;
    for kind in PROTOS {
        let mut cfg = cfg_for(kind, ChurnModel::Bernoulli);
        cfg.env.fabric = fabric.clone();
        cfg.env.faults = FaultPlan {
            enabled: true,
            crash_hazard: 0.4,
            flap_prob: 0.7,
            flap_downtime_s: 5.0,
            ..FaultPlan::default()
        };
        let mut env = FedEnv::new(&cfg).unwrap();
        let payload = env.net.model_bytes; // no codec
        let mut proto = make_protocol(&env);
        for t in 1..=10 {
            let rec = proto.run_round(t, &mut env);
            let label = format!("{} t={t}", kind.name());
            for (name, bytes, floor) in [
                ("down", rec.bytes_down, rec.m_sync as f64 * payload),
                ("up", rec.bytes_up, rec.n_committed as f64 * payload),
            ] {
                let excess = bytes - floor;
                assert!(
                    excess > -1e-6,
                    "{label}: bytes_{name} {bytes} fell below the \
                     one-copy-per-transfer floor {floor}"
                );
                let copies = excess / payload;
                assert!(
                    (copies - copies.round()).abs() < 1e-6,
                    "{label}: bytes_{name} excess {excess} is not a whole \
                     number of {payload}-byte payloads"
                );
                if copies.round() > 0.0 {
                    saw_excess = true;
                }
            }
        }
    }
    assert!(
        saw_excess,
        "no protocol ever re-sent a payload over 10 lossy chaos rounds — \
         the retransmit books went unexercised"
    );
}

#[test]
fn books_match_traffic_with_quantizing_codec() {
    // 8-bit stochastic quantization of f32 payloads: ratio 8/32.
    let fabric = FabricConfig::from_parts(
        "none",
        None,
        None,
        None,
        None,
        None,
        None,
        None,
        Some("quantize"),
        None,
        Some(8),
    )
    .unwrap();
    for kind in PROTOS {
        for churn in churns() {
            let mut cfg = cfg_for(kind, churn);
            cfg.env.fabric = fabric.clone();
            assert_books(&cfg, 8.0 / 32.0, 8);
        }
    }
}
