//! End-to-end integration tests over the native backend: full federated
//! runs per protocol per task, cross-protocol metric relationships, and
//! the paper's qualitative claims at reduced scale.

use safa::config::{presets, Backend, ProtocolKind};
use safa::coordinator::run_experiment;
use safa::util::proptest::property;

#[test]
fn every_protocol_times_every_task_profile() {
    // Timing-only runs at the real Table II profiles (m up to 500) —
    // cheap because the Null backend skips numerics.
    for preset_name in ["task1", "task2", "task3"] {
        for kind in ProtocolKind::ALL {
            let mut cfg = presets::preset(preset_name).unwrap();
            cfg.backend = Backend::Null;
            cfg.protocol.kind = kind;
            cfg.train.rounds = 6;
            cfg.eval_every = 1_000_000; // no eval
            let r = run_experiment(&cfg)
                .unwrap_or_else(|e| panic!("{preset_name}/{kind:?}: {e}"));
            assert_eq!(r.rounds.len(), 6);
            for rec in &r.rounds {
                assert!(rec.round_len >= rec.t_dist);
                assert!(rec.round_len <= cfg.train.t_lim + rec.t_dist + 1e-9);
                assert!(rec.n_committed + rec.n_crashed <= cfg.env.m);
            }
        }
    }
}

#[test]
fn sr_matches_paper_structure() {
    // Table XI/XIII/XV structure: FedAvg SR == C exactly; SAFA SR tracks
    // the commit rate (≈ 1 - cr) instead of C.
    let mut cfg = presets::preset("task2").unwrap();
    cfg.backend = Backend::Null;
    cfg.train.rounds = 30;
    cfg.eval_every = 1_000_000;
    cfg.protocol.c_fraction = 0.3;
    cfg.env.crash_prob = 0.3;

    cfg.protocol.kind = ProtocolKind::FedAvg;
    let fedavg = run_experiment(&cfg).unwrap();
    assert!(
        (fedavg.sync_ratio() - 0.3).abs() < 1e-9,
        "FedAvg SR {} != C",
        fedavg.sync_ratio()
    );

    cfg.protocol.kind = ProtocolKind::Safa;
    let safa = run_experiment(&cfg).unwrap();
    let sr = safa.sync_ratio();
    assert!(
        (sr - 0.7).abs() < 0.12,
        "SAFA SR {sr} should track 1-cr=0.7 (paper Table XIII: ~0.71)"
    );
}

#[test]
fn eur_ordering_safa_above_fedavg() {
    // Eq. 5 / Fig. 2: SAFA's post-training selection dominates FedAvg's
    // EUR whenever crashes occur.
    property("EUR(SAFA) >= EUR(FedAvg) - eps", 8, |g| {
        let cr = g.f64_range(0.2, 0.8);
        let c = *g.choose(&[0.1, 0.3, 0.5]);
        let mut cfg = presets::preset("task2").unwrap();
        cfg.backend = Backend::Null;
        cfg.train.rounds = 15;
        cfg.eval_every = 1_000_000;
        cfg.protocol.c_fraction = c;
        cfg.env.crash_prob = cr;
        cfg.seed = g.u64() % 1000;
        cfg.protocol.kind = ProtocolKind::Safa;
        let safa = run_experiment(&cfg).unwrap().eur();
        cfg.protocol.kind = ProtocolKind::FedAvg;
        let fedavg = run_experiment(&cfg).unwrap().eur();
        assert!(
            safa >= fedavg - 0.05,
            "C={c} cr={cr}: EUR safa {safa} < fedavg {fedavg}"
        );
    });
}

#[test]
fn futility_structure_matches_paper() {
    // Tables XI/XIII/XV: FedAvg futility ≈ cr/2, SAFA ≤ a few percent.
    let mut cfg = presets::preset("task2").unwrap();
    cfg.backend = Backend::Null;
    cfg.train.rounds = 40;
    cfg.eval_every = 1_000_000;
    cfg.protocol.c_fraction = 0.5;
    for cr in [0.3, 0.7] {
        cfg.env.crash_prob = cr;
        cfg.protocol.kind = ProtocolKind::FedAvg;
        let f = run_experiment(&cfg).unwrap().futility();
        assert!(
            (f - cr / 2.0).abs() < 0.08,
            "FedAvg futility {f} should be near cr/2 = {}",
            cr / 2.0
        );
        cfg.protocol.kind = ProtocolKind::Safa;
        let s = run_experiment(&cfg).unwrap().futility();
        assert!(s < 0.10, "SAFA futility {s} should be small (paper < 0.04)");
        assert!(s < f, "SAFA futility {s} must beat FedAvg {f}");
    }
}

#[test]
fn safa_round_efficiency_headline_task2() {
    // Table VI's headline: at C=0.1 with crashes, SAFA rounds are an
    // order of magnitude shorter than FedAvg's deadline-bound rounds.
    let mut cfg = presets::preset("task2").unwrap();
    cfg.backend = Backend::Null;
    cfg.train.rounds = 20;
    cfg.eval_every = 1_000_000;
    cfg.protocol.c_fraction = 0.1;
    cfg.env.crash_prob = 0.3;
    cfg.protocol.kind = ProtocolKind::Safa;
    let safa = run_experiment(&cfg).unwrap().avg_round_len();
    cfg.protocol.kind = ProtocolKind::FedAvg;
    let fedavg = run_experiment(&cfg).unwrap().avg_round_len();
    cfg.protocol.kind = ProtocolKind::FedCs;
    let fedcs = run_experiment(&cfg).unwrap().avg_round_len();
    assert!(
        safa * 4.0 < fedavg,
        "SAFA {safa}s should be >=4x faster than FedAvg {fedavg}s (paper: up to 27x)"
    );
    assert!(
        fedcs < fedavg,
        "FedCS {fedcs}s should beat FedAvg {fedavg}s"
    );
    assert!(
        safa < fedcs,
        "SAFA {safa}s should beat FedCS {fedcs}s (paper: up to 6x)"
    );
}

#[test]
fn quality_runs_complete_on_all_tasks_scaled() {
    // Real training on heavily reduced configs — smoke that the three
    // native trainers integrate with every protocol.
    for (preset_name, n, m, rounds) in
        [("task1", 120usize, 4usize, 5usize), ("task3-scaled", 2_000, 10, 3)]
    {
        for kind in [ProtocolKind::Safa, ProtocolKind::FedAvg] {
            let mut cfg = presets::preset(preset_name).unwrap();
            cfg.protocol.kind = kind;
            cfg.task.n = n;
            cfg.task.n_test = 100;
            cfg.env.m = m;
            cfg.train.rounds = rounds;
            let r = run_experiment(&cfg)
                .unwrap_or_else(|e| panic!("{preset_name}/{kind:?}: {e}"));
            assert!(r.best_loss().unwrap().is_finite());
        }
    }
    // CNN: tiniest viable run.
    let mut cfg = presets::preset("task2-scaled").unwrap();
    cfg.task.n = 200;
    cfg.task.n_test = 80;
    cfg.env.m = 4;
    cfg.train.rounds = 2;
    cfg.task.cnn = safa::config::CnnArch {
        c1: 4,
        c2: 8,
        hidden: 32,
    };
    let r = run_experiment(&cfg).unwrap();
    assert!(r.best_accuracy().unwrap() > 0.05);
}
