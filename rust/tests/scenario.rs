//! Scenario-engine integration contracts:
//!
//! * **Reductions** — a scenario compiled from per-round transitions
//!   (`Scenario::bernoulli` / `Scenario::markov`) reproduces the legacy
//!   `env.crash_prob` / `env.churn` runs bit-for-bit, for every
//!   protocol. This pins the RNG-stream contract: the reductions stay
//!   on the per-(round, client) streams.
//! * **Width invariance** — with the full battery on (diurnal dwells on
//!   the continuous clock, a flash-crowd join burst + departures, a
//!   regional outage, the contended fabric and the fault injectors)
//!   whole SAFA runs stay bit-identical across fork widths {1, 3, 8}.
//! * **Dynamic membership** — the flashcrowd preset actually moves the
//!   fleet: latecomers carry `joined_round`, departures carry
//!   `departed_round`, and the join burst pays distribution time on the
//!   contended server link.

use safa::config::{presets, ChurnModel, ProtocolKind};
use safa::coordinator::{run_experiment, Coordinator};
use safa::metrics::RunResult;
use safa::scenario::Scenario;
use safa::util::parallel::with_thread_count;

const WIDTHS: [usize; 3] = [1, 3, 8];

/// Everything a round reports, as raw bits where floats are involved.
fn fingerprint(r: &RunResult) -> Vec<(u64, u64, usize, usize, usize, usize, u64)> {
    r.rounds
        .iter()
        .map(|rec| {
            (
                rec.round_len.to_bits(),
                rec.t_dist.to_bits(),
                rec.m_sync,
                rec.n_picked,
                rec.n_committed,
                rec.n_crashed,
                rec.train_loss.to_bits(),
            )
        })
        .collect()
}

fn final_bits(r: &RunResult) -> (u64, u64) {
    let e = r.final_eval.expect("final eval");
    (e.loss.to_bits(), e.accuracy.to_bits())
}

#[test]
fn bernoulli_reduction_reproduces_legacy_runs_bit_for_bit() {
    for kind in ProtocolKind::ALL {
        let mut legacy = presets::preset("tiny").unwrap();
        legacy.protocol.kind = kind;
        legacy.env.crash_prob = 0.3;
        legacy.train.rounds = 5;

        let mut scenario = legacy.clone();
        // The superseded legacy knob must not leak into the pinned
        // reduction, so give it a junk value on purpose.
        scenario.env.crash_prob = 0.9;
        scenario.env.scenario = Scenario::bernoulli(0.3).build().unwrap();

        let a = run_experiment(&legacy).unwrap();
        let b = run_experiment(&scenario).unwrap();
        assert_eq!(
            fingerprint(&a),
            fingerprint(&b),
            "{kind:?}: bernoulli reduction diverged from legacy crash_prob"
        );
        assert_eq!(final_bits(&a), final_bits(&b), "{kind:?}: final eval");
    }
}

#[test]
fn markov_reduction_reproduces_legacy_churn_bit_for_bit() {
    for kind in ProtocolKind::ALL {
        let mut legacy = presets::preset("tiny").unwrap();
        legacy.protocol.kind = kind;
        legacy.env.churn = ChurnModel::Markov {
            mean_uptime_s: 500.0,
            mean_downtime_s: 200.0,
        };
        legacy.train.rounds = 5;

        let mut scenario = legacy.clone();
        // The scenario overrides whatever `env.churn` says.
        scenario.env.churn = ChurnModel::Bernoulli;
        scenario.env.scenario = Scenario::markov(500.0, 200.0).build().unwrap();

        let a = run_experiment(&legacy).unwrap();
        let b = run_experiment(&scenario).unwrap();
        assert_eq!(
            fingerprint(&a),
            fingerprint(&b),
            "{kind:?}: markov reduction diverged from legacy churn"
        );
        assert_eq!(final_bits(&a), final_bits(&b), "{kind:?}: final eval");
    }
}

/// Scenario-off runs must be bit-for-bit untouched by this machinery:
/// the default (disabled) spec and no spec at all are the same run.
#[test]
fn disabled_scenario_is_bit_for_bit_inert() {
    for kind in [ProtocolKind::Safa, ProtocolKind::FedAvg] {
        let mut cfg = presets::preset("tiny").unwrap();
        cfg.protocol.kind = kind;
        cfg.train.rounds = 5;
        let a = run_experiment(&cfg).unwrap();
        cfg.env.scenario = safa::scenario::ScenarioSpec::default();
        let b = run_experiment(&cfg).unwrap();
        assert_eq!(fingerprint(&a), fingerprint(&b), "{kind:?}: inertness");
        assert_eq!(final_bits(&a), final_bits(&b), "{kind:?}: final eval");
    }
}

/// The heaviest configuration in the repo: continuous diurnal dwells,
/// a mid-run join burst and departures, a regional outage, the
/// contended fabric (FIFO server link, lognormal client links, loss +
/// retransmits) and the chaos injectors, all at once — bit-identical
/// at every fork width.
#[test]
fn scenario_runs_are_width_invariant_end_to_end() {
    let chaos = presets::preset("chaos").unwrap();
    let mut cfg = presets::preset("flashcrowd").unwrap();
    cfg.env.m = 60;
    cfg.train.rounds = 6;
    cfg.env.faults = chaos.env.faults.clone();
    cfg.env.scenario = Scenario::new()
        .uptime(cfg.train.t_lim * 0.6, cfg.train.t_lim * 0.25)
        .diurnal(0.6, cfg.train.t_lim * 4.0)
        .regions(4)
        .at_round(3)
        .flash_crowd(10, 0)
        .at_round(5)
        .flash_crowd(0, 5)
        .at_round(4)
        .regional_outage(1, cfg.train.t_lim * 0.5)
        .build()
        .unwrap();

    let run = |width: usize| -> (Vec<(u64, u64, usize, usize, usize, usize, u64)>, (u64, u64)) {
        with_thread_count(width, || {
            let r = run_experiment(&cfg).unwrap();
            (fingerprint(&r), final_bits(&r))
        })
    };
    let reference = run(1);
    for &width in &WIDTHS[1..] {
        let got = run(width);
        assert_eq!(got, reference, "scenario width {width}: run diverged");
    }
}

/// Flash crowds move the fleet for real: the flashcrowd preset's join
/// burst stamps `joined_round`, the departures stamp `departed_round`,
/// rounds before the burst run without the latecomers, and the join
/// round pays distribution time on the contended server link.
#[test]
fn flashcrowd_preset_changes_membership_and_pays_distribution() {
    let mut cfg = presets::preset("flashcrowd").unwrap();
    cfg.train.rounds = 6;
    let mut coord = Coordinator::new(&cfg).unwrap();
    let result = coord.run();

    let joined: Vec<usize> = coord
        .env
        .clients
        .iter()
        .filter(|c| c.joined_round == Some(3))
        .map(|c| c.id)
        .collect();
    assert_eq!(joined.len(), 10, "round-3 join burst: {joined:?}");
    let departed = coord
        .env
        .clients
        .iter()
        .filter(|c| c.departed_round.is_some())
        .count();
    assert!(departed >= 5, "round-5 departures, got {departed}");

    // Latecomers sit out the early rounds entirely.
    for t in [1usize, 2] {
        for &k in &joined {
            assert!(
                !coord.env.is_member(t, k),
                "latecomer {k} must not be a member in round {t}"
            );
        }
    }
    // The join burst forces a sync for the whole new cohort, so round 3
    // distributes to at least the 10 latecomers and pays serialized
    // time for it on the contended server link.
    let r3 = &result.rounds[2];
    assert!(
        r3.m_sync >= 10,
        "join burst must force-sync the cohort: m_sync {}",
        r3.m_sync
    );
    assert!(
        r3.t_dist > 0.0,
        "join burst should queue on the server link: t_dist {}",
        r3.t_dist
    );
}
