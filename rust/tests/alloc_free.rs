//! Zero-allocation steady state for the fleet engine (perf satellite).
//!
//! The telemetry layer's counting allocator (`safa::telemetry::
//! CountingAlloc`) wraps the system allocator; after a warm-up round has
//! grown every pooled buffer (`RoundScratch`, the event queue, the
//! reusable output records), further rounds must not touch the heap at
//! all — for the Bernoulli direct path AND the Markov event path, at
//! width 1 AND under pooled parallel dispatch, with telemetry recording
//! OFF and ON (spans + counters live on the hot path are shard-atomic
//! adds and clock reads, never heap traffic), and with the network
//! fabric off AND fully on (contended + heterogeneous + perturbed
//! transfers draw from stack-constructed per-transfer streams; the
//! download-wait table is pooled in `RoundScratch`), and with the
//! fault-injection engine armed (chaos-profile injectors: cancellable
//! transfer legs, bounded retries and crash-info reporting all live in
//! pooled scratch).
//!
//! The serial case is strict by construction. The pooled case is the
//! persistent worker pool's contract: warm-up rounds spawn + park the
//! workers (stacks, join handles — counted, hence warm-up) and build
//! every per-worker buffer; a steady-state park/wake broadcast then
//! passes the job by stack pointer and touches no heap. Only the legacy
//! `SAFA_DISPATCH=spawn` dispatcher still pays per-fork allocations,
//! which is why the test pins `Dispatch::Pooled`. Exactly one #[test]
//! lives in this binary so no concurrent test pollutes the counter.
//!
//! The test also points `SAFA_TRACE` at a scratch file before the first
//! engine call, so every measured pass runs with the client-lifecycle
//! stream LIVE: each picked/trained/uploaded/crashed client formats a
//! JSONL line straight into the trace writer's pre-grown `BufWriter`
//! via `core::fmt` (stack buffers only) and flushes (a syscall, not an
//! allocation). Zero steady-state allocations must hold with the trace
//! on — that is the observability tentpole's perf contract.
//!
//! The continuous scenario timeline (diurnal dwells, flash crowds,
//! regional outages) is held to the same bar: its cursors and window
//! table are allocated at compile time and `prepare_round` walks them
//! in place, so scenario rounds — membership transitions included —
//! must be heap-free at steady state too.

use safa::client::ClientState;
use safa::config::presets;
use safa::engine::{AvailabilityModel, FleetEngine, RoundCtx, ScenarioTimeline};
use safa::scenario::Scenario;
use safa::faults::{FaultPlan, FaultRuntime};
use safa::model::ParamVec;
use safa::net::fabric::{FabricConfig, FabricRuntime};
use safa::net::NetworkModel;
use safa::sim::{ContinuationSim, RoundSim};
use safa::telemetry::{self, Counter};
use safa::util::parallel::{with_dispatch, with_thread_count, Dispatch};
use safa::util::rng::Pcg64;

#[global_allocator]
static GLOBAL: telemetry::CountingAlloc = telemetry::CountingAlloc;

fn fleet(m: usize) -> Vec<ClientState> {
    let mut rng = Pcg64::new(99);
    (0..m)
        .map(|id| ClientState {
            id,
            perf: 0.05 + rng.next_f64() * 3.0,
            batches_per_epoch: 1 + rng.index(40),
            n_k: 10,
            local_model: ParamVec::zeros(1),
            version: 0,
            base_version: 0,
            committed_last: true,
            picked_last: false,
            pending_partial: 0.0,
            job: None,
            joined_round: None,
            departed_round: None,
        })
        .collect()
}

/// Drive `rounds` fresh-job + continuation rounds through one engine,
/// reusing the output records, and return the allocation count observed
/// after the warm-up rounds.
fn allocs_in_steady_state(
    avail: AvailabilityModel,
    m: usize,
    warmup: usize,
    rounds: usize,
    fabric_on: bool,
    faults_on: bool,
    scenario_on: bool,
) -> u64 {
    let mut cfg = presets::preset("tiny").unwrap();
    cfg.env.m = m;
    cfg.env.crash_prob = 0.2;
    if faults_on {
        // Every injector armed (the chaos profile): crash/flap cuts,
        // correlated outages, link degradation, bounded retries — the
        // faults event path must be heap-free at steady state too.
        cfg.env.faults = FaultPlan {
            enabled: true,
            crash_hazard: 0.15,
            flap_prob: 0.5,
            flap_downtime_s: 30.0,
            regions: 2,
            outage_prob: 0.1,
            outage_len_s: 60.0,
            degrade_prob: 0.2,
            degrade_factor: 2.0,
            ..FaultPlan::default()
        };
    }
    if fabric_on {
        // Contended + heterogeneous + perturbed: every fabric code path
        // that can run inside the engine is on the measured hot path.
        cfg.env.fabric = FabricConfig::from_parts(
            "fifo",
            None,
            Some("lognormal"),
            Some(0.5),
            Some(0.05),
            Some(0.02),
            Some(0.02),
            None,
            None,
            None,
            None,
        )
        .unwrap();
    }
    // Built outside the measured window (the link table is one Vec);
    // per-transfer draws construct no heap state.
    let fabric = cfg.env.fabric.enabled.then(|| FabricRuntime::new(&cfg.env, 7));
    let faults = cfg.env.faults.enabled.then(|| FaultRuntime::new(&cfg));
    let net = NetworkModel::new(&cfg.env);
    let clients = fleet(m);
    let participants: Vec<usize> = (0..m).collect();
    let synced: Vec<bool> = (0..m).map(|k| k % 2 == 0).collect();
    let jobs: Vec<f64> = (0..m).map(|k| 40.0 + 11.0 * k as f64).collect();
    // Trailing upload legs for the faults continuation path (built
    // outside the measured window, like every other input buffer).
    let tails: Vec<f64> = jobs.iter().map(|j| 0.3 * j).collect();
    let mut engine = FleetEngine::new(avail, m);
    if scenario_on {
        // The full continuous battery: diurnal dwells plus a mid-window
        // flash crowd and a regional outage, both of which land inside
        // the *measured* rounds — membership transitions must be
        // heap-free, not just quiet dwelling.
        let spec = Scenario::new()
            .uptime(cfg.train.t_lim * 0.6, cfg.train.t_lim * 0.25)
            .diurnal(0.6, cfg.train.t_lim * 4.0)
            .regions(2)
            .at_round(warmup + 2)
            .flash_crowd(10, 5)
            .at_round(warmup + 4)
            .regional_outage(1, cfg.train.t_lim * 0.5)
            .build()
            .expect("scenario spec");
        engine.set_scenario(ScenarioTimeline::new(&spec, m, cfg.train.t_lim, 11));
    }
    let mut round_out = RoundSim::default();
    let mut cont_out = ContinuationSim::default();

    let mut run = |engine: &mut FleetEngine,
                   t: usize,
                   ro: &mut RoundSim,
                   co: &mut ContinuationSim| {
        let rng = Pcg64::new(5).split(t as u64);
        let ctx = RoundCtx {
            cfg: &cfg,
            net: &net,
            clients: &clients,
            fabric: fabric.as_ref(),
            faults: faults.as_ref(),
        };
        engine.run_round_into(t, ctx, &participants, &synced, &rng, ro);
        let rng2 = Pcg64::new(6).split(t as u64);
        if let Some(fr) = faults.as_ref() {
            engine.run_continuation_faults_into(
                t,
                &cfg,
                &participants,
                &jobs,
                &tails,
                fabric.as_ref(),
                fr,
                &rng2,
                co,
            );
        } else {
            engine.run_continuation_into(t, &cfg, &participants, &jobs, &rng2, co);
        }
    };

    for t in 1..=warmup {
        run(&mut engine, t, &mut round_out, &mut cont_out);
    }
    let before = telemetry::alloc_count();
    for t in warmup + 1..=warmup + rounds {
        run(&mut engine, t, &mut round_out, &mut cont_out);
    }
    telemetry::alloc_count() - before
}

#[test]
fn steady_state_rounds_do_not_allocate() {
    let m = 500;
    // Consume telemetry's one-shot environment read here, outside every
    // measured window (`env::var` allocates); afterwards the enable flag
    // is one relaxed atomic.
    telemetry::set_enabled(false);
    // Arm the lifecycle trace BEFORE any engine call: the TRACE OnceLock
    // is first-call-wins, and the engine's own `lifecycle::active()`
    // probe would otherwise pin it to None for the whole process. With
    // the trace live, every measured round below also writes client
    // lifecycle lines — emission must be allocation-free too.
    telemetry::lifecycle::set_sample_stride(1);
    let trace_path =
        std::env::temp_dir().join(format!("safa_alloc_free_trace_{}.jsonl", std::process::id()));
    let trace_str = trace_path.to_string_lossy().into_owned();
    assert!(
        telemetry::set_trace(&trace_str),
        "cannot open lifecycle trace destination {trace_str}"
    );
    for telemetry_on in [false, true] {
        telemetry::set_enabled(telemetry_on);
        let mode = if telemetry_on {
            "telemetry on"
        } else {
            "telemetry off"
        };
        // Serial path: strictly zero heap traffic.
        with_thread_count(1, || {
            let bern = allocs_in_steady_state(
                AvailabilityModel::BernoulliPerRound { crash_prob: 0.2 },
                m,
                3,
                8,
                false,
                false,
                false,
            );
            assert_eq!(bern, 0, "Bernoulli direct path allocated ({mode})");
            let markov = allocs_in_steady_state(
                AvailabilityModel::Markov {
                    mean_uptime_s: 400.0,
                    mean_downtime_s: 150.0,
                },
                m,
                3,
                8,
                false,
                false,
                false,
            );
            assert_eq!(markov, 0, "Markov event path allocated ({mode})");
            let fab_bern = allocs_in_steady_state(
                AvailabilityModel::BernoulliPerRound { crash_prob: 0.2 },
                m,
                3,
                8,
                true,
                false,
                false,
            );
            assert_eq!(fab_bern, 0, "fabric Bernoulli path allocated ({mode})");
            let fab_markov = allocs_in_steady_state(
                AvailabilityModel::Markov {
                    mean_uptime_s: 400.0,
                    mean_downtime_s: 150.0,
                },
                m,
                3,
                8,
                true,
                false,
                false,
            );
            assert_eq!(fab_markov, 0, "fabric Markov event path allocated ({mode})");
            // Faults event path, with and without the contended fabric:
            // injector queries, cancellable legs, retries and the
            // crash-info report all ride pooled buffers.
            let faults_bern = allocs_in_steady_state(
                AvailabilityModel::BernoulliPerRound { crash_prob: 0.2 },
                m,
                3,
                8,
                false,
                true,
                false,
            );
            assert_eq!(faults_bern, 0, "faults Bernoulli path allocated ({mode})");
            let faults_fab = allocs_in_steady_state(
                AvailabilityModel::Markov {
                    mean_uptime_s: 400.0,
                    mean_downtime_s: 150.0,
                },
                m,
                3,
                8,
                true,
                true,
                false,
            );
            assert_eq!(
                faults_fab, 0,
                "faults + fabric Markov event path allocated ({mode})"
            );
            // Continuous scenario timeline on the contended fabric, with
            // a flash crowd and a regional outage inside the measured
            // window.
            let scen = allocs_in_steady_state(
                AvailabilityModel::BernoulliPerRound { crash_prob: 0.2 },
                m,
                3,
                8,
                true,
                false,
                true,
            );
            assert_eq!(scen, 0, "scenario timeline path allocated ({mode})");
        });
        // Pooled dispatch at width 4 (m=500 over the 64-client draw
        // grain genuinely forks): after warm-up spawns and parks the
        // pool's workers, steady-state parallel rounds allocate nothing
        // either.
        with_dispatch(Dispatch::Pooled, || {
            with_thread_count(4, || {
                let bern = allocs_in_steady_state(
                    AvailabilityModel::BernoulliPerRound { crash_prob: 0.2 },
                    m,
                    3,
                    8,
                    false,
                    false,
                    false,
                );
                assert_eq!(bern, 0, "pooled Bernoulli direct path allocated ({mode})");
                let markov = allocs_in_steady_state(
                    AvailabilityModel::Markov {
                        mean_uptime_s: 400.0,
                        mean_downtime_s: 150.0,
                    },
                    m,
                    3,
                    8,
                    false,
                    false,
                    false,
                );
                assert_eq!(markov, 0, "pooled Markov event path allocated ({mode})");
                let fab_markov = allocs_in_steady_state(
                    AvailabilityModel::Markov {
                        mean_uptime_s: 400.0,
                        mean_downtime_s: 150.0,
                    },
                    m,
                    3,
                    8,
                    true,
                    false,
                    false,
                );
                assert_eq!(
                    fab_markov, 0,
                    "pooled fabric Markov event path allocated ({mode})"
                );
                let faults_fab = allocs_in_steady_state(
                    AvailabilityModel::Markov {
                        mean_uptime_s: 400.0,
                        mean_downtime_s: 150.0,
                    },
                    m,
                    3,
                    8,
                    true,
                    true,
                    false,
                );
                assert_eq!(
                    faults_fab, 0,
                    "pooled faults + fabric event path allocated ({mode})"
                );
                // Scenario timeline under pooled parallel dispatch: the
                // chunked cursor walk fans out across the workers.
                let scen = allocs_in_steady_state(
                    AvailabilityModel::BernoulliPerRound { crash_prob: 0.2 },
                    m,
                    3,
                    8,
                    true,
                    false,
                    true,
                );
                assert_eq!(
                    scen, 0,
                    "pooled scenario timeline path allocated ({mode})"
                );
            });
        });
    }
    // The telemetry-on passes must actually have exercised live
    // instrumentation: the Markov event path pops queue events, so the
    // cumulative counter cannot still be zero.
    let snap = telemetry::snapshot();
    assert!(
        snap.counter(Counter::EventsPopped) > 0,
        "telemetry-on rounds recorded no event pops — instrumentation dead?"
    );
    // And the lifecycle stream must actually have been live throughout:
    // client lines landed in the trace file and none were dropped.
    assert_eq!(
        telemetry::trace_dropped(),
        0,
        "lifecycle trace writes were dropped"
    );
    let trace = std::fs::read_to_string(&trace_path).expect("read lifecycle trace");
    assert!(
        trace.lines().any(|l| l.contains("\"type\":\"client\"")),
        "no client lifecycle lines in trace — emission dead?"
    );
    let _ = std::fs::remove_file(&trace_path);
    telemetry::set_enabled(false);
}
