//! Thread-count invariance: the parallel runtime must be bit-for-bit
//! identical to the serial path at every fork width.
//!
//! Covers the parallelized hot paths from the perf tentpoles:
//! * engine window draws + round outcomes (Bernoulli direct path,
//!   Markov event path with persisted churn state and fleet-chunked
//!   setup passes, trace replay),
//! * Eq. 7 `weighted_sum_into` / `weighted_sum_slices_into`,
//! * full protocol rounds on the Null backend (SAFA end to end),
//! * full protocol rounds on the native CNN backend (Task 2), whose
//!   client updates train in per-worker scratch slots on the
//!   persistent pool.
//!
//! Widths {1, 3, 8} × fleet sizes m ∈ {1, 7, 500}, per the issue's test
//! matrix. Equality is asserted on raw f64 bits, not tolerances.

use safa::client::ClientState;
use safa::config::{presets, Backend, ChurnModel, CnnArch};
use safa::engine::{AvailabilityModel, FleetEngine, RoundCtx};
use safa::model::{weighted_sum_into, weighted_sum_slices_into, ParamVec};
use safa::net::NetworkModel;
use safa::protocol::{FedEnv, Protocol, Safa};
use safa::sim::{ContinuationSim, RoundSim};
use safa::util::parallel::with_thread_count;
use safa::util::rng::Pcg64;

const WIDTHS: [usize; 3] = [1, 3, 8];
const FLEETS: [usize; 3] = [1, 7, 500];

/// A deterministic synthetic fleet (no dataset needed — the engine only
/// reads timing fields).
fn fleet(m: usize) -> Vec<ClientState> {
    let mut rng = Pcg64::new(0xf1ee7 ^ m as u64);
    (0..m)
        .map(|id| ClientState {
            id,
            perf: 0.05 + rng.next_f64() * 3.0,
            batches_per_epoch: 1 + rng.index(40),
            n_k: 10,
            local_model: ParamVec::zeros(1),
            version: 0,
            base_version: 0,
            committed_last: true,
            picked_last: false,
            pending_partial: 0.0,
            job: None,
            joined_round: None,
            departed_round: None,
        })
        .collect()
}

fn assert_round_bits_eq(a: &RoundSim, b: &RoundSim, ctx: &str) {
    assert_eq!(a.arrivals.len(), b.arrivals.len(), "{ctx}: arrival count");
    for (x, y) in a.arrivals.iter().zip(&b.arrivals) {
        assert_eq!(x.client, y.client, "{ctx}: arrival order");
        assert_eq!(x.time.to_bits(), y.time.to_bits(), "{ctx}: arrival time");
    }
    assert_eq!(a.failures.len(), b.failures.len(), "{ctx}: failure count");
    for (&(ka, ra, pa), &(kb, rb, pb)) in a.failures.iter().zip(&b.failures) {
        assert_eq!(ka, kb, "{ctx}: failed client");
        assert_eq!(ra, rb, "{ctx}: failure reason");
        assert_eq!(pa.to_bits(), pb.to_bits(), "{ctx}: failure partial");
    }
    assert_eq!(
        a.online_time.to_bits(),
        b.online_time.to_bits(),
        "{ctx}: online_time"
    );
    assert_eq!(
        a.offline_time.to_bits(),
        b.offline_time.to_bits(),
        "{ctx}: offline_time"
    );
    assert_eq!(a.last_drop.to_bits(), b.last_drop.to_bits(), "{ctx}: last_drop");
}

fn assert_cont_bits_eq(a: &ContinuationSim, b: &ContinuationSim, ctx: &str) {
    assert_eq!(a.arrivals.len(), b.arrivals.len(), "{ctx}: arrival count");
    for (x, y) in a.arrivals.iter().zip(&b.arrivals) {
        assert_eq!(x.client, y.client, "{ctx}: arrival order");
        assert_eq!(x.time.to_bits(), y.time.to_bits(), "{ctx}: arrival time");
    }
    assert_eq!(a.crashed, b.crashed, "{ctx}: crashed set");
    assert_eq!(a.stragglers, b.stragglers, "{ctx}: stragglers");
    assert_eq!(
        a.online_time.to_bits(),
        b.online_time.to_bits(),
        "{ctx}: online_time"
    );
}

/// Run `rounds` engine rounds (fresh engine per width so Markov state
/// evolves from the same origin) and return every record.
fn engine_rounds(
    avail: &AvailabilityModel,
    clients: &[ClientState],
    rounds: usize,
) -> (Vec<RoundSim>, Vec<ContinuationSim>) {
    let m = clients.len();
    let mut cfg = presets::preset("tiny").unwrap();
    cfg.env.m = m;
    cfg.env.crash_prob = 0.3;
    let net = NetworkModel::new(&cfg.env);
    let mut engine = FleetEngine::new(avail.clone(), m);
    let participants: Vec<usize> = (0..m).collect();
    let synced: Vec<bool> = (0..m).map(|k| k % 2 == 0).collect();
    let jobs: Vec<f64> = (0..m).map(|k| 50.0 + 37.0 * k as f64).collect();
    let mut round_outs = Vec::new();
    let mut cont_outs = Vec::new();
    for t in 1..=rounds {
        let rng = Pcg64::new(42).split(t as u64);
        let ctx = RoundCtx {
            cfg: &cfg,
            net: &net,
            clients,
            fabric: None,
            faults: None,
        };
        round_outs.push(engine.run_round(t, ctx, &participants, &synced, &rng));
        let rng2 = Pcg64::new(43).split(t as u64);
        cont_outs.push(engine.run_continuation(t, &cfg, &participants, &jobs, &rng2));
    }
    (round_outs, cont_outs)
}

/// Satellite: parallel vs sequential window draws are bit-identical
/// across widths {1, 3, 8} and m ∈ {1, 7, 500} for all three
/// availability models (Markov included — per-client streams and state
/// cells make the chunking invisible).
#[test]
fn engine_rounds_are_width_invariant() {
    let models = [
        AvailabilityModel::BernoulliPerRound { crash_prob: 0.3 },
        AvailabilityModel::Markov {
            mean_uptime_s: 400.0,
            mean_downtime_s: 150.0,
        },
        AvailabilityModel::Trace {
            rounds: vec![
                vec![true, false, true, true],
                vec![false, true, true, false],
            ],
        },
    ];
    for model in &models {
        for &m in &FLEETS {
            let clients = fleet(m);
            let reference = with_thread_count(1, || engine_rounds(model, &clients, 6));
            for &width in &WIDTHS[1..] {
                let got = with_thread_count(width, || engine_rounds(model, &clients, 6));
                for (t, (a, b)) in got.0.iter().zip(&reference.0).enumerate() {
                    assert_round_bits_eq(a, b, &format!("{model:?} m={m} w={width} t={t}"));
                }
                for (t, (a, b)) in got.1.iter().zip(&reference.1).enumerate() {
                    assert_cont_bits_eq(a, b, &format!("{model:?} m={m} w={width} cont t={t}"));
                }
            }
        }
    }
}

/// Satellite: parallel vs serial `weighted_sum_into` is bit-identical
/// across widths and entry counts (the chunked fold keeps the per-entry
/// order fixed per coordinate).
#[test]
fn weighted_sum_is_width_invariant() {
    for &m in &FLEETS {
        // Dim large enough that width 8 genuinely forks (grain 4096).
        let dim = 40_000;
        let mut rng = Pcg64::new(7 + m as u64);
        let entries: Vec<ParamVec> = (0..m)
            .map(|_| ParamVec((0..dim).map(|_| rng.next_f32() - 0.5).collect()))
            .collect();
        let weights: Vec<f32> = (0..m).map(|_| rng.next_f32()).collect();
        let pairs: Vec<(f32, &ParamVec)> = weights.iter().copied().zip(entries.iter()).collect();

        let mut reference = ParamVec::zeros(dim);
        with_thread_count(1, || weighted_sum_into(&mut reference, &pairs));
        for &width in &WIDTHS {
            let mut got = ParamVec::zeros(dim);
            with_thread_count(width, || weighted_sum_into(&mut got, &pairs));
            assert!(got == reference, "weighted_sum_into m={m} width={width}");
            let mut got2 = ParamVec::zeros(dim);
            with_thread_count(width, || {
                weighted_sum_slices_into(&mut got2, &weights, &entries)
            });
            assert!(got2 == reference, "weighted_sum_slices m={m} width={width}");
        }
    }
}

/// Tentpole: Task-2 (native CNN) client updates fan out across the
/// persistent pool in per-worker scratch slots; whole SAFA runs on the
/// CNN backend must stay bit-identical at every width — training,
/// Eq. 7 aggregation and engine rounds included — under both Bernoulli
/// crashes and Markov churn.
#[test]
fn safa_cnn_rounds_are_width_invariant_end_to_end() {
    for churn in [
        ChurnModel::Bernoulli,
        ChurnModel::Markov {
            mean_uptime_s: 500.0,
            mean_downtime_s: 200.0,
        },
    ] {
        let mut cfg = presets::preset("task2-scaled").unwrap();
        cfg.backend = Backend::Native;
        cfg.env.churn = churn.clone();
        cfg.env.m = 80; // enough arrivals that widths genuinely fork
        cfg.env.crash_prob = 0.1;
        cfg.task.n = 400;
        cfg.task.n_test = 40;
        cfg.task.cnn = CnnArch {
            c1: 2,
            c2: 2,
            hidden: 8,
        };
        cfg.train.batch_size = 8;
        cfg.train.epochs = 1;
        cfg.train.rounds = 2;

        let run = |width: usize| -> Vec<(usize, usize, Vec<u32>)> {
            with_thread_count(width, || {
                let mut env = FedEnv::new(&cfg).unwrap();
                let mut safa = Safa::new(&env, env.init_global());
                (1..=cfg.train.rounds)
                    .map(|t| {
                        let rec = safa.run_round(t, &mut env);
                        // The global model's exact bits, every coordinate.
                        let bits: Vec<u32> =
                            safa.global().as_slice().iter().map(|x| x.to_bits()).collect();
                        (rec.n_picked, rec.n_committed, bits)
                    })
                    .collect()
            })
        };
        let reference = run(1);
        for &width in &WIDTHS[1..] {
            let got = run(width);
            assert_eq!(got.len(), reference.len());
            for (t, (a, b)) in got.iter().zip(&reference).enumerate() {
                assert_eq!(a.0, b.0, "{churn:?} cnn width {width} t={t}: n_picked");
                assert_eq!(a.1, b.1, "{churn:?} cnn width {width} t={t}: n_committed");
                assert_eq!(a.2, b.2, "{churn:?} cnn width {width} t={t}: global bits");
            }
        }
    }
}

/// End-to-end: whole SAFA runs on the Null backend produce bit-identical
/// global models, round records and client states at every width —
/// including under Markov churn (the paper's protocol metrics are
/// therefore width-independent).
#[test]
fn safa_rounds_are_width_invariant_end_to_end() {
    for churn in [
        ChurnModel::Bernoulli,
        ChurnModel::Markov {
            mean_uptime_s: 500.0,
            mean_downtime_s: 200.0,
        },
    ] {
        let mut cfg = presets::preset("fleet10k").unwrap();
        cfg.env.m = 500; // keep the test fast; widths still fork
        cfg.task.n = 5_000;
        cfg.env.churn = churn.clone();
        cfg.train.rounds = 4;

        let run = |width: usize| -> Vec<(f64, usize, usize, u64)> {
            with_thread_count(width, || {
                let mut env = FedEnv::new(&cfg).unwrap();
                let mut safa = Safa::new(&env, env.init_global());
                (1..=cfg.train.rounds)
                    .map(|t| {
                        let rec = safa.run_round(t, &mut env);
                        // Round length, commit split and the global
                        // model's exact bits.
                        let g = safa.global().as_slice()[0] as f64;
                        (rec.round_len, rec.n_picked, rec.n_committed, g.to_bits())
                    })
                    .collect()
            })
        };
        let reference = run(1);
        for &width in &WIDTHS[1..] {
            let got = run(width);
            assert_eq!(got.len(), reference.len());
            for (t, (a, b)) in got.iter().zip(&reference).enumerate() {
                assert_eq!(
                    a.0.to_bits(),
                    b.0.to_bits(),
                    "{churn:?} width {width} t={t}: round_len"
                );
                assert_eq!(a.1, b.1, "{churn:?} width {width} t={t}: n_picked");
                assert_eq!(a.2, b.2, "{churn:?} width {width} t={t}: n_committed");
                assert_eq!(a.3, b.3, "{churn:?} width {width} t={t}: global bits");
            }
        }
    }
}

/// Network-fabric tentpole: with the event fabric fully on — FIFO
/// server-link contention, heterogeneous lognormal client links,
/// latency + jitter + loss with retransmits, and top-k update
/// compression — whole SAFA runs stay bit-identical at every width,
/// under Bernoulli crashes and Markov churn. Per-transfer times and the
/// codec draw from dedicated per-(round, client) streams, so the
/// parallel fan-out cannot reorder them.
#[test]
fn safa_fabric_rounds_are_width_invariant_end_to_end() {
    for churn in [
        ChurnModel::Bernoulli,
        ChurnModel::Markov {
            mean_uptime_s: 500.0,
            mean_downtime_s: 200.0,
        },
    ] {
        let mut cfg = presets::preset("fleet10k").unwrap();
        cfg.env.m = 300; // keep the test fast; widths still fork
        cfg.task.n = 3_000;
        cfg.env.churn = churn.clone();
        cfg.train.rounds = 4;
        cfg.env.fabric = safa::net::fabric::FabricConfig::from_parts(
            "fifo",
            None,
            Some("lognormal"),
            Some(0.5),
            Some(0.05),
            Some(0.02),
            Some(0.02),
            None,
            Some("topk"),
            Some(0.25),
            None,
        )
        .unwrap();

        let run = |width: usize| -> Vec<(u64, usize, usize, u64)> {
            with_thread_count(width, || {
                let mut env = FedEnv::new(&cfg).unwrap();
                let mut safa = Safa::new(&env, env.init_global());
                (1..=cfg.train.rounds)
                    .map(|t| {
                        let rec = safa.run_round(t, &mut env);
                        let g = safa.global().as_slice()[0] as f64;
                        (
                            rec.round_len.to_bits(),
                            rec.n_picked,
                            rec.n_committed,
                            g.to_bits(),
                        )
                    })
                    .collect()
            })
        };
        let reference = run(1);
        for &width in &WIDTHS[1..] {
            let got = run(width);
            assert_eq!(
                got, reference,
                "{churn:?} fabric width {width}: run diverged"
            );
        }
    }
}

/// Observability tentpole: recording telemetry (span timers, fleet
/// counters) must not perturb the simulation — it only reads clocks and
/// bumps shard atomics, never consumes RNG or reorders reductions. SAFA
/// runs with telemetry force-enabled are bit-identical to runs with it
/// off, at every width. (Toggling the process-global flag mid-suite is
/// safe precisely because of this invariant.)
#[test]
fn telemetry_recording_does_not_perturb_results() {
    let mut cfg = presets::preset("fleet10k").unwrap();
    cfg.env.m = 200;
    cfg.task.n = 2_000;
    cfg.env.churn = ChurnModel::Markov {
        mean_uptime_s: 500.0,
        mean_downtime_s: 200.0,
    };
    cfg.train.rounds = 3;

    let run = |width: usize, telemetry: bool| -> Vec<(u64, usize, usize, u64)> {
        let prior = safa::telemetry::enabled();
        safa::telemetry::set_enabled(telemetry);
        let out = with_thread_count(width, || {
            let mut env = FedEnv::new(&cfg).unwrap();
            let mut proto = Safa::new(&env, env.init_global());
            (1..=cfg.train.rounds)
                .map(|t| {
                    let rec = proto.run_round(t, &mut env);
                    let g = proto.global().as_slice()[0] as f64;
                    (
                        rec.round_len.to_bits(),
                        rec.n_picked,
                        rec.n_committed,
                        g.to_bits(),
                    )
                })
                .collect()
        });
        safa::telemetry::set_enabled(prior);
        out
    };
    let reference = run(1, false);
    for &width in &WIDTHS {
        for telemetry in [false, true] {
            let got = run(width, telemetry);
            assert_eq!(
                got, reference,
                "telemetry={telemetry} width={width}: run diverged"
            );
        }
    }
}
