//! Integration tests for the PJRT runtime: load the AOT artifacts, run
//! local updates and evaluation through XLA, and check numeric agreement
//! with the pure-Rust native backend on identical batches.
//!
//! These tests skip (pass trivially with a notice) when `artifacts/` has
//! not been built — run `make artifacts` first for full coverage. The
//! whole file is gated on the `xla` feature: the default offline build
//! carries only the stub trainer (see `runtime/stub.rs`).

#![cfg(feature = "xla")]

use safa::config::{presets, Backend, ExperimentConfig};
use safa::coordinator::Coordinator;
use safa::data::{partition_gaussian, synth, FedData};
use safa::model::{make_trainer, Trainer};
use safa::runtime::{Manifest, XlaTrainer};
use safa::util::rng::Pcg64;
use std::sync::Arc;

fn artifacts_ready() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

fn skip_notice(test: &str) {
    eprintln!("SKIP {test}: artifacts/ missing — run `make artifacts`");
}

/// Config matching the regression artifact shapes.
fn regression_cfg() -> ExperimentConfig {
    let mut cfg = presets::preset("task1").unwrap();
    cfg.backend = Backend::Xla;
    cfg.train.rounds = 5;
    cfg
}

fn make_data(cfg: &ExperimentConfig) -> Arc<FedData> {
    let (train, test) = synth::generate(cfg.task.kind, cfg.task.n, cfg.task.n_test, cfg.seed);
    let mut rng = Pcg64::with_stream(cfg.seed, 0x9a57);
    let partitions = partition_gaussian(train.n, cfg.env.m, cfg.env.partition_rel_std, &mut rng);
    Arc::new(FedData {
        train,
        test,
        partitions,
    })
}

#[test]
fn manifest_loads_and_describes_all_tasks() {
    if !artifacts_ready() {
        skip_notice("manifest_loads");
        return;
    }
    let m = Manifest::load("artifacts").unwrap();
    for task in ["regression", "cnn", "svm"] {
        let t = m.task(task).unwrap();
        assert!(t.param_dim > 0);
        assert!(std::path::Path::new("artifacts").join(&t.train_hlo).exists());
        assert!(std::path::Path::new("artifacts").join(&t.eval_hlo).exists());
    }
}

#[test]
fn xla_local_update_agrees_with_native_backend() {
    if !artifacts_ready() {
        skip_notice("xla_vs_native");
        return;
    }
    let cfg = regression_cfg();
    let data = make_data(&cfg);
    let mut xla = XlaTrainer::new(&cfg, Arc::clone(&data)).expect("load artifacts");
    let mut native = make_trainer(
        &ExperimentConfig {
            backend: Backend::Native,
            ..cfg.clone()
        },
        Arc::clone(&data),
    );
    assert_eq!(xla.dim(), native.dim(), "param dim mismatch");
    let base = native.init_params(&mut Pcg64::new(7));
    for client in 0..cfg.env.m {
        // Identical RNG stream -> identical batch order in both backends.
        let ux = xla.local_update(&base, client, &mut Pcg64::new(42));
        let un = native.local_update(&base, client, &mut Pcg64::new(42));
        let dist = ux.params.dist(&un.params);
        let norm = un.params.norm().max(1e-9);
        assert!(
            dist / norm < 1e-4,
            "client {client}: XLA vs native param distance {dist} (rel {})",
            dist / norm
        );
        assert!(
            (ux.train_loss - un.train_loss).abs() < 1e-3 * (1.0 + un.train_loss.abs()),
            "client {client}: loss {} vs {}",
            ux.train_loss,
            un.train_loss
        );
    }
}

#[test]
fn xla_eval_agrees_with_native_backend() {
    if !artifacts_ready() {
        skip_notice("xla_eval");
        return;
    }
    let cfg = regression_cfg();
    let data = make_data(&cfg);
    let mut xla = XlaTrainer::new(&cfg, Arc::clone(&data)).expect("load artifacts");
    let mut native = make_trainer(
        &ExperimentConfig {
            backend: Backend::Native,
            ..cfg.clone()
        },
        Arc::clone(&data),
    );
    let params = native.init_params(&mut Pcg64::new(11));
    let ex = xla.evaluate(&params);
    let en = native.evaluate(&params);
    assert!(
        (ex.loss - en.loss).abs() < 1e-3 * (1.0 + en.loss.abs()),
        "loss {} vs {}",
        ex.loss,
        en.loss
    );
    assert!(
        (ex.accuracy - en.accuracy).abs() < 1e-4,
        "acc {} vs {}",
        ex.accuracy,
        en.accuracy
    );
}

#[test]
fn full_federated_run_on_xla_backend() {
    if !artifacts_ready() {
        skip_notice("xla_full_run");
        return;
    }
    let cfg = regression_cfg();
    let data = make_data(&cfg);
    let trainer = XlaTrainer::new(&cfg, Arc::clone(&data)).expect("load artifacts");
    let mut coord = Coordinator::with_trainer(&cfg, data, Box::new(trainer)).unwrap();
    let result = coord.run();
    assert_eq!(result.rounds.len(), 5);
    let first = result.rounds[0].eval.unwrap().loss;
    let last = result.rounds[4].eval.unwrap().loss;
    assert!(
        last < first,
        "XLA-backed federated training should reduce loss: {first} -> {last}"
    );
}

#[test]
fn xla_svm_task_runs() {
    if !artifacts_ready() {
        skip_notice("xla_svm");
        return;
    }
    let mut cfg = presets::preset("task3-scaled").unwrap();
    cfg.backend = Backend::Xla;
    cfg.task.n = 2_000; // keep shards within the artifact's max_batches
    cfg.task.n_test = 4_000;
    cfg.env.m = 20;
    let data = make_data(&cfg);
    let mut xla = XlaTrainer::new(&cfg, Arc::clone(&data)).expect("load artifacts");
    let mut native = make_trainer(
        &ExperimentConfig {
            backend: Backend::Native,
            ..cfg.clone()
        },
        Arc::clone(&data),
    );
    let base = native.init_params(&mut Pcg64::new(5));
    let ux = xla.local_update(&base, 0, &mut Pcg64::new(9));
    let un = native.local_update(&base, 0, &mut Pcg64::new(9));
    let rel = ux.params.dist(&un.params) / un.params.norm().max(1e-9);
    assert!(rel < 1e-4, "svm xla/native relative distance {rel}");
}
