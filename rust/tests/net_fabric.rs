//! Event-driven network fabric: integration acceptance tests.
//!
//! The tentpole contract has two halves:
//! * **Off = seed.** With `fabric = "off"` (the default) every protocol
//!   runs the closed-form Eqs. 17–19 arithmetic — literally the legacy
//!   code path, checked here against the closed form bit-for-bit.
//! * **Neutral = off.** Enabling the fabric with an uncontended, fixed,
//!   loss-free, uncompressed config must reproduce the fabric-off run
//!   bit-for-bit: the event fabric generalizes the closed form, it does
//!   not replace it with something merely close.
//!
//! On top of that, contention only ever stretches rounds, and update
//! compression scales the comm-cost books by the codec ratio.

use safa::config::{presets, ChurnModel, ExperimentConfig, ProtocolKind};
use safa::net::fabric::FabricConfig;
use safa::protocol::{make_protocol, FedEnv};

/// Per-round fingerprint: every timing/accounting output bit-compared.
#[derive(Debug, PartialEq)]
struct Fingerprint {
    round_len: u64,
    t_dist: u64,
    m_sync: usize,
    n_picked: usize,
    n_committed: usize,
    bytes_down: u64,
    bytes_up: u64,
    global: Vec<u32>,
}

fn run_rounds(cfg: &ExperimentConfig, rounds: usize) -> Vec<Fingerprint> {
    let mut env = FedEnv::new(cfg).unwrap();
    let mut proto = make_protocol(&env);
    (1..=rounds)
        .map(|t| {
            let rec = proto.run_round(t, &mut env);
            Fingerprint {
                round_len: rec.round_len.to_bits(),
                t_dist: rec.t_dist.to_bits(),
                m_sync: rec.m_sync,
                n_picked: rec.n_picked,
                n_committed: rec.n_committed,
                bytes_down: rec.bytes_down.to_bits(),
                bytes_up: rec.bytes_up.to_bits(),
                global: proto
                    .global()
                    .as_slice()
                    .iter()
                    .map(|x| x.to_bits())
                    .collect(),
            }
        })
        .collect()
}

fn base_cfg(kind: ProtocolKind, churn: ChurnModel) -> ExperimentConfig {
    let mut cfg = presets::preset("tiny").unwrap();
    cfg.protocol.kind = kind;
    cfg.env.crash_prob = 0.2;
    cfg.env.churn = churn;
    cfg.seed = 11;
    cfg
}

/// A fabric that is enabled but models exactly the closed-form network:
/// no contention, fixed links, no latency/jitter/loss, no compression.
fn neutral_fabric() -> FabricConfig {
    FabricConfig::from_parts(
        "none", None, None, None, None, None, None, None, None, None, None,
    )
    .unwrap()
}

/// Acceptance: the neutral-enabled fabric reproduces the fabric-off run
/// bit-for-bit — for every protocol, under Bernoulli crashes and Markov
/// churn (direct and event engine paths, fresh-job and continuation
/// protocol paths).
#[test]
fn neutral_fabric_is_bit_identical_to_fabric_off() {
    let churns = [
        ChurnModel::Bernoulli,
        ChurnModel::Markov {
            mean_uptime_s: 400.0,
            mean_downtime_s: 150.0,
        },
    ];
    for churn in &churns {
        for kind in ProtocolKind::ALL {
            let off = base_cfg(kind, churn.clone());
            let mut neutral = off.clone();
            neutral.env.fabric = neutral_fabric();
            assert!(neutral.env.fabric.enabled);
            let a = run_rounds(&off, 5);
            let b = run_rounds(&neutral, 5);
            assert_eq!(
                a,
                b,
                "{}/{churn:?}: neutral fabric diverged from fabric-off",
                kind.name()
            );
        }
    }
}

/// Regression: with the fabric off, per-round outputs satisfy the
/// closed-form Eqs. 17–19 arithmetic exactly (bitwise, not within a
/// tolerance): `T_dist = m_sync · t_per_model` and the comm-cost books
/// are whole model copies.
#[test]
fn fabric_off_reproduces_closed_form_arithmetic() {
    for kind in [ProtocolKind::Safa, ProtocolKind::FedAvg, ProtocolKind::FedAsync] {
        let cfg = base_cfg(kind, ChurnModel::Bernoulli);
        let env = FedEnv::new(&cfg).unwrap();
        let (t_per_model, model_bytes) = (env.net.t_per_model, env.net.model_bytes);
        drop(env);
        for (t, f) in run_rounds(&cfg, 5).iter().enumerate() {
            assert_eq!(
                f.t_dist,
                (f.m_sync as f64 * t_per_model).to_bits(),
                "{} t={t}: T_dist != Eq. 19",
                kind.name()
            );
            assert_eq!(
                f.bytes_down,
                (f.m_sync as f64 * model_bytes).to_bits(),
                "{} t={t}: downlink bytes",
                kind.name()
            );
            assert_eq!(
                f.bytes_up,
                (f.n_committed as f64 * model_bytes).to_bits(),
                "{} t={t}: uplink bytes",
                kind.name()
            );
        }
    }
}

/// FIFO contention adds nonnegative head-of-line waits and changes
/// nothing else in a neutral fabric. FedAvg with crash-free rounds and
/// an uncapped deadline makes that comparable round by round (its
/// timing carries no state between rounds, unlike SAFA's continuation
/// jobs): every arrival is delayed pointwise, so every round is at
/// least as long, the total T_dist calibration is unchanged, and with
/// a slow server link the queue tail dominates — rounds get strictly
/// longer.
#[test]
fn fifo_contention_only_stretches_rounds() {
    let mut base = base_cfg(ProtocolKind::FedAvg, ChurnModel::Bernoulli);
    base.env.m = 12;
    base.protocol.c_fraction = 1.0;
    base.env.crash_prob = 0.0;
    base.train.t_lim = 1e9;
    // Slow server link: one copy-time dwarfs any training-time spread,
    // so the back of the FIFO queue provably determines the round.
    base.env.server_bw_bps = 1e3;
    let mut neutral = base.clone();
    neutral.env.fabric = neutral_fabric();
    let mut fifo = base.clone();
    fifo.env.fabric = FabricConfig::from_parts(
        "fifo", None, None, None, None, None, None, None, None, None, None,
    )
    .unwrap();
    let a = run_rounds(&neutral, 3);
    let b = run_rounds(&fifo, 3);
    for (t, (n, f)) in a.iter().zip(&b).enumerate() {
        let (ln, lf) = (f64::from_bits(n.round_len), f64::from_bits(f.round_len));
        assert!(
            lf > ln,
            "t={t}: FIFO round {lf} not longer than uncontended {ln}"
        );
        // Queueing reshuffles who waits, not the total distribution
        // cost: T_dist = m_sync · t_per_model under every policy.
        assert_eq!(n.t_dist, f.t_dist, "t={t}: contention changed T_dist");
        assert_eq!(n.m_sync, f.m_sync, "t={t}: contention changed the sync set");
    }
}

/// Top-k compression scales both directions of the comm-cost books by
/// the codec ratio (value+index pairs: ratio = 2·fraction) and reports
/// the savings.
#[test]
fn compression_scales_the_comm_cost_books() {
    let mut cfg = base_cfg(ProtocolKind::FedAvg, ChurnModel::Bernoulli);
    cfg.env.fabric = FabricConfig::from_parts(
        "none",
        None,
        None,
        None,
        None,
        None,
        None,
        None,
        Some("topk"),
        Some(0.25),
        None,
    )
    .unwrap();
    let env = FedEnv::new(&cfg).unwrap();
    let model_bytes = env.net.model_bytes;
    drop(env);
    let mut env = FedEnv::new(&cfg).unwrap();
    let mut proto = make_protocol(&env);
    let ratio = 0.5; // 2 × 0.25
    for t in 1..=4 {
        let rec = proto.run_round(t, &mut env);
        assert!(
            (rec.bytes_down - rec.m_sync as f64 * model_bytes * ratio).abs() < 1e-6,
            "t={t}: downlink not ratio-scaled"
        );
        assert!(
            (rec.bytes_up - rec.n_committed as f64 * model_bytes * ratio).abs() < 1e-6,
            "t={t}: uplink not ratio-scaled"
        );
        let expected_saved =
            (rec.m_sync + rec.n_committed) as f64 * model_bytes * (1.0 - ratio);
        assert!(
            (rec.bytes_saved - expected_saved).abs() < 1e-6,
            "t={t}: bytes_saved {} != {expected_saved}",
            rec.bytes_saved
        );
    }
}

/// The `contended` preset drives every protocol end to end (lognormal
/// heterogeneous links, FIFO contention, latency/jitter/loss): smoke for
/// the full fabric configuration space reachable from a preset name.
#[test]
fn contended_preset_runs_every_protocol() {
    for kind in ProtocolKind::ALL {
        let mut cfg = presets::preset("contended").unwrap();
        cfg.protocol.kind = kind;
        cfg.env.m = 8;
        cfg.task.n = 200;
        cfg.task.n_test = 20;
        let prints = run_rounds(&cfg, 3);
        assert_eq!(prints.len(), 3, "{}: contended run truncated", kind.name());
        for f in &prints {
            assert!(f64::from_bits(f.round_len).is_finite());
        }
    }
}
