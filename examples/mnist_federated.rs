//! End-to-end driver: federated CNN training through the full
//! three-layer stack — Rust SAFA coordinator → PJRT runtime → HLO
//! artifacts lowered from the JAX model with its Pallas fused-linear
//! kernel. Python never runs here; build artifacts first:
//!
//! ```bash
//! make artifacts
//! cargo run --release --offline --example mnist_federated
//! ```
//!
//! Trains the Task-2 CNN on synthetic MNIST-like digits across 20
//! clients under 20% crashes, comparing SAFA against FedAvg, and logs
//! both loss curves (also written to results/). Falls back to the
//! numerically-equivalent native backend with a notice if artifacts are
//! missing.

use safa::bench_harness::Series;
use safa::config::{presets, Backend, CnnArch, ExperimentConfig, ProtocolKind};
use safa::coordinator::Coordinator;
use safa::data::{partition_gaussian, synth, FedData};
use safa::metrics::RunResult;
use safa::runtime::XlaTrainer;
use safa::util::rng::Pcg64;
use std::sync::Arc;

fn config() -> ExperimentConfig {
    let mut cfg = presets::preset("task2-scaled").unwrap();
    cfg.env.m = 20;
    cfg.task.n = 1_600; // ~80 images per client
    cfg.task.n_test = 800;
    cfg.task.cnn = CnnArch::scaled(); // must match the artifact manifest
    cfg.train.rounds = 12;
    cfg.train.epochs = 2;
    cfg.env.crash_prob = 0.2;
    cfg.protocol.c_fraction = 0.3;
    cfg
}

fn run(kind: ProtocolKind, use_xla: bool) -> Result<RunResult, Box<dyn std::error::Error>> {
    let mut cfg = config();
    cfg.protocol.kind = kind;
    cfg.backend = if use_xla { Backend::Xla } else { Backend::Native };
    let (train, test) = synth::generate(cfg.task.kind, cfg.task.n, cfg.task.n_test, cfg.seed);
    let mut rng = Pcg64::with_stream(cfg.seed, 0x9a57);
    let partitions = partition_gaussian(train.n, cfg.env.m, cfg.env.partition_rel_std, &mut rng);
    let data = Arc::new(FedData {
        train,
        test,
        partitions,
    });
    let mut coord = if use_xla {
        let trainer = XlaTrainer::new(&cfg, Arc::clone(&data))?;
        Coordinator::with_trainer(&cfg, data, Box::new(trainer))?
    } else {
        Coordinator::with_data(&cfg, data)?
    };
    Ok(coord.run())
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    safa::util::logging::init();
    // The XLA path needs both the AOT artifacts on disk and a build with
    // the `xla` feature (the default build ships a stub trainer).
    let use_xla =
        cfg!(feature = "xla") && std::path::Path::new("artifacts/manifest.json").exists();
    if use_xla {
        println!("backend: XLA (PJRT executing the JAX/Pallas AOT artifacts)");
    } else {
        println!(
            "backend: native (run `make artifacts` and build with --features xla for the XLA path)"
        );
    }

    let safa_run = run(ProtocolKind::Safa, use_xla)?;
    let fedavg_run = run(ProtocolKind::FedAvg, use_xla)?;

    println!("\nround  SAFA loss  FedAvg loss   SAFA len(s)  FedAvg len(s)");
    for (a, b) in safa_run.rounds.iter().zip(&fedavg_run.rounds) {
        println!(
            "{:>5}  {:>9.4}  {:>11.4}  {:>12.1}  {:>13.1}",
            a.round,
            a.eval.map(|e| e.loss).unwrap_or(f64::NAN),
            b.eval.map(|e| e.loss).unwrap_or(f64::NAN),
            a.round_len,
            b.round_len,
        );
    }
    println!(
        "\nSAFA:   best acc {:.4}, avg round {:.0}s, futility {:.3}",
        safa_run.best_accuracy().unwrap_or(f64::NAN),
        safa_run.avg_round_len(),
        safa_run.futility()
    );
    println!(
        "FedAvg: best acc {:.4}, avg round {:.0}s, futility {:.3}",
        fedavg_run.best_accuracy().unwrap_or(f64::NAN),
        fedavg_run.avg_round_len(),
        fedavg_run.futility()
    );

    let x: Vec<f64> = (1..=safa_run.rounds.len()).map(|r| r as f64).collect();
    let mut s = Series::new("mnist_federated loss curves", "round", x);
    s.add_line("SAFA", safa_run.loss_trace());
    s.add_line("FedAvg", fedavg_run.loss_trace());
    s.emit("example_mnist_federated");
    Ok(())
}
