//! The §III-D study as a runnable example: how the single SAFA
//! hyper-parameter (lag tolerance tau) trades communication (SR) against
//! model staleness (VV) and quality (best loss).
//!
//! ```bash
//! SAFA_BENCH_FAST=1 cargo run --release --offline --example lag_tolerance_sweep
//! ```

use safa::experiments::tau_sweep;

fn main() {
    safa::util::logging::init();
    let sweep = tau_sweep();
    for (label, loss, sr, _eur, vv) in &sweep.lines {
        println!("--- {label} ---");
        println!("{:>4} {:>12} {:>8} {:>8}", "tau", "best_loss", "SR", "VV");
        for (i, &tau) in sweep.taus.iter().enumerate() {
            println!(
                "{:>4} {:>12.4} {:>8.3} {:>8.3}",
                tau, loss[i], sr[i], vv[i]
            );
        }
    }
    println!(
        "\nPaper takeaway (§III-D): small tau inflates SR (communication),\n\
         large tau inflates VV (staleness) and hurts loss under high cr;\n\
         tau ≈ 5 is the recommended middle ground."
    );
}
