//! Churn scenarios: the three availability models of the fleet engine,
//! side by side, on a tiny federation.
//!
//! ```bash
//! cargo run --release --offline --example churn_scenarios
//! ```
//!
//! Runs SAFA and the FedAsync baseline under (1) the paper's per-round
//! Bernoulli crashes, (2) two-state Markov on/off churn with mid-round
//! drops/recoveries, and (3) a deterministic trace replay (written to
//! `results/churn_trace_demo.txt` and loaded back through the config),
//! then prints round length, effective-update ratio, the fraction of
//! client-time spent online, and the staleness histogram of what each
//! protocol actually merged.

use safa::bench_harness::write_results_file;
use safa::config::{presets, ChurnModel, ExperimentConfig, ProtocolKind};
use safa::coordinator::run_experiment;

const TRACE_PATH: &str = "results/churn_trace_demo.txt";

fn scenarios() -> Result<Vec<(&'static str, ChurnModel)>, Box<dyn std::error::Error>> {
    // A harsh deterministic pattern: every round a different client pair
    // is offline; one fully-online breather round in four.
    write_results_file(TRACE_PATH, "0011\n1001\n1100\n1111\n")?;
    Ok(vec![
        ("bernoulli (paper)", ChurnModel::Bernoulli),
        (
            "markov on/off",
            ChurnModel::Markov {
                mean_uptime_s: 500.0,
                mean_downtime_s: 200.0,
            },
        ),
        (
            "trace replay",
            ChurnModel::Trace {
                path: TRACE_PATH.to_string(),
            },
        ),
    ])
}

fn base_config() -> Result<ExperimentConfig, Box<dyn std::error::Error>> {
    let mut cfg = presets::preset("tiny")?;
    cfg.train.rounds = 16;
    cfg.env.crash_prob = 0.3; // only the Bernoulli scenario reads this
    cfg.protocol.c_fraction = 0.5;
    Ok(cfg)
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    safa::util::logging::init();
    println!(
        "{:<18} {:<9} {:>12} {:>7} {:>8} {:>8}  staleness histogram",
        "scenario", "protocol", "round_len(s)", "EUR", "online", "best_l"
    );
    for (name, churn) in scenarios()? {
        for kind in [ProtocolKind::Safa, ProtocolKind::FedAsync] {
            let mut cfg = base_config()?;
            cfg.env.churn = churn.clone();
            cfg.protocol.kind = kind;
            let r = run_experiment(&cfg)?;
            println!(
                "{:<18} {:<9} {:>12.1} {:>7.3} {:>8.3} {:>8.4}  {:?}",
                name,
                r.protocol,
                r.avg_round_len(),
                r.eur(),
                r.avg_online_fraction(),
                r.best_loss().unwrap_or(f64::NAN),
                r.staleness_histogram(),
            );
        }
    }
    println!("\ntrace written to {TRACE_PATH} (edit it and re-run to replay your own outages)");
    Ok(())
}
