//! Domain example: federated network-intrusion detection (the paper's
//! Task 3) — a linear SVM over 35 TCP-connection features, 200 edge
//! clients, high unreliability (cr = 0.5). The scenario the paper's
//! introduction motivates: many flaky devices, expensive uplinks.
//!
//! ```bash
//! cargo run --release --offline --example intrusion_detection
//! ```

use safa::config::{presets, ProtocolKind};
use safa::coordinator::run_with_data;
use safa::experiments::shared_data;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    safa::util::logging::init();
    let mut cfg = presets::preset("task3-scaled")?;
    cfg.env.m = 200;
    cfg.task.n = 12_000;
    cfg.task.n_test = 3_000;
    cfg.train.rounds = 25;
    cfg.env.crash_prob = 0.5;
    cfg.protocol.c_fraction = 0.1;

    let data = shared_data(&cfg);
    println!(
        "federating intrusion detection over {} clients (cr={}, C={})\n",
        cfg.env.m, cfg.env.crash_prob, cfg.protocol.c_fraction
    );
    println!("{:<12} {:>10} {:>12} {:>10} {:>9}", "protocol", "best acc", "avg round(s)", "SR", "futility");
    for kind in ProtocolKind::ALL {
        let mut c = cfg.clone();
        c.protocol.kind = kind;
        let r = run_with_data(&c, data.clone())?;
        println!(
            "{:<12} {:>10.4} {:>12.1} {:>10.3} {:>9.3}",
            r.protocol,
            r.best_accuracy().unwrap_or(f64::NAN),
            r.avg_round_len(),
            r.sync_ratio(),
            r.futility()
        );
    }
    println!(
        "\nExpected shape (paper Tables VIII/XIV): SAFA reaches the same\n\
         >99% accuracy ceiling while its rounds are several times shorter\n\
         than FedAvg's and its futility stays near zero."
    );
    Ok(())
}
