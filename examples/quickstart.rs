//! Quickstart: a tiny SAFA federation on the synthetic regression task.
//!
//! ```bash
//! cargo run --release --offline --example quickstart
//! ```
//!
//! Builds a 4-client federation, runs 10 SAFA rounds with 10% crashes,
//! and prints the per-round loss plus the paper's summary metrics.

use safa::config::presets;
use safa::coordinator::run_experiment;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    safa::util::logging::init();

    // Start from the `tiny` preset and tweak it like a user would.
    let mut cfg = presets::preset("tiny")?;
    cfg.train.rounds = 10;
    cfg.env.crash_prob = 0.1;
    cfg.protocol.c_fraction = 0.5; // server closes a round at 50% picks
    cfg.protocol.tau = 3; // lag tolerance (the one SAFA knob)

    let result = run_experiment(&cfg)?;

    println!("round  length(s)  picked  committed  loss");
    for r in &result.rounds {
        println!(
            "{:>5}  {:>9.1}  {:>6}  {:>9}  {:.4}",
            r.round,
            r.round_len,
            r.n_picked,
            r.n_committed,
            r.eval.map(|e| e.loss).unwrap_or(f64::NAN)
        );
    }
    println!();
    println!("avg round length : {:>8.1} s", result.avg_round_len());
    println!("sync ratio (SR)  : {:>8.3}", result.sync_ratio());
    println!("EUR              : {:>8.3}", result.eur());
    println!("version variance : {:>8.3}", result.version_variance());
    println!("futility         : {:>8.3}", result.futility());
    println!(
        "best accuracy    : {:>8.4}",
        result.best_accuracy().unwrap_or(f64::NAN)
    );
    Ok(())
}
