//! The §III-E bias analysis as a runnable example: prints the Fig. 5
//! series (paper-verbatim and corrected) for a chosen crash rate and
//! explains the three selection cases.
//!
//! ```bash
//! cargo run --release --offline --example bias_analysis -- 0.3
//! ```

use safa::analysis::{
    bias_fedavg, bias_safa, bias_safa_paper, classify_case, BiasCase,
};

fn main() {
    safa::util::logging::init();
    let cr: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.3);

    println!("selection-case boundaries at R = {cr}:");
    for c in [0.05, 0.1, 0.3, 0.5, 0.7, 0.9] {
        println!("  C = {c:<4} -> {:?}", classify_case(c, cr));
    }

    println!("\nbias vs round (cr_A = cr_B = {cr}):");
    println!(
        "{:>5} {:>8} {:>14} {:>14} {:>14}",
        "round", "FedAvg", "case2(paper)", "case2(corr.)", "case3(paper)"
    );
    for r in 1..=12u32 {
        println!(
            "{:>5} {:>8.3} {:>14.3} {:>14.3} {:>14.3}",
            r,
            bias_fedavg(cr, cr),
            bias_safa_paper(BiasCase::Case2, cr, cr, r),
            bias_safa(BiasCase::Case2, cr, cr, r),
            bias_safa_paper(BiasCase::Case3, cr, cr, r),
        );
    }
    println!(
        "\nNote: the paper-verbatim series uses Eqs. 13-16 as printed,\n\
         whose sigma (Eq. 15) exceeds 1 — see the erratum note in\n\
         src/analysis/mod.rs. The corrected column evaluates the same\n\
         recurrences with valid probabilities."
    );
}
