"""L1 correctness: Pallas kernels vs the pure-jnp oracles in ref.py.

Hypothesis sweeps shapes; tolerances are f32-tight because both paths
compute in f32.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.fused_linear import fused_linear, matmul_pallas
from compile.kernels.ref import ref_linear, ref_sgd
from compile.kernels.sgd import sgd_update

jax.config.update("jax_platform_name", "cpu")


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 40),
    n=st.integers(1, 70),
    act=st.sampled_from(["none", "relu"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_pallas_matches_ref(m, k, n, act, seed):
    rng = np.random.default_rng(seed)
    x, w, b = rand(rng, m, k), rand(rng, k, n), rand(rng, n)
    got = matmul_pallas(x, w, b, act)
    want = ref_linear(x, w, b, act)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_matmul_pallas_blocks_larger_than_tile():
    # Exercise multiple grid steps on both axes (tile = 128).
    rng = np.random.default_rng(0)
    x, w, b = rand(rng, 300, 17), rand(rng, 17, 260), rand(rng, 260)
    np.testing.assert_allclose(
        matmul_pallas(x, w, b, "relu"),
        ref_linear(x, w, b, "relu"),
        rtol=1e-5,
        atol=1e-5,
    )


def test_matmul_pallas_no_bias():
    rng = np.random.default_rng(1)
    x, w = rand(rng, 9, 5), rand(rng, 5, 3)
    np.testing.assert_allclose(
        matmul_pallas(x, w), ref_linear(x, w), rtol=1e-5, atol=1e-6
    )


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 20),
    k=st.integers(1, 16),
    n=st.integers(1, 20),
    act=st.sampled_from(["none", "relu"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_linear_gradients_match_ref(m, k, n, act, seed):
    rng = np.random.default_rng(seed)
    x, w, b = rand(rng, m, k), rand(rng, k, n), rand(rng, n)

    def f_pallas(x, w, b):
        return jnp.sum(fused_linear(x, w, b, act) ** 2)

    def f_ref(x, w, b):
        return jnp.sum(ref_linear(x, w, b, act) ** 2)

    gp = jax.grad(f_pallas, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, c in zip(gp, gr):
        np.testing.assert_allclose(a, c, rtol=2e-4, atol=2e-4)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 5000),
    lr=st.floats(1e-5, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_sgd_update_matches_ref(n, lr, seed):
    rng = np.random.default_rng(seed)
    p = rand(rng, n)
    g = rand(rng, n)
    np.testing.assert_allclose(
        sgd_update(p, g, lr), ref_sgd(p, g, lr), rtol=1e-6, atol=1e-6
    )


def test_fused_linear_relu_zeroes_negative_grads():
    # Direct check of the fused activation's vjp masking.
    x = jnp.asarray([[1.0, -1.0]])
    w = jnp.asarray([[1.0], [0.0]])
    b = jnp.asarray([-2.0])  # pre-act = -1 -> relu clamps to 0

    def f(x):
        return jnp.sum(fused_linear(x, w, b, "relu"))

    g = jax.grad(f)(x)
    np.testing.assert_allclose(g, jnp.zeros_like(x))
