"""L2 correctness: task models vs hand-rolled numpy SGD, masking
invariants, and shape checks for every task spec."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    MASK_SENTINEL,
    SVM_L2,
    TaskSpec,
    build,
    default_specs,
)

jax.config.update("jax_platform_name", "cpu")


def spec_by_name(name):
    return next(s for s in default_specs() if s.name == name)


def make_batches(rng, spec, n_real, labels="reg"):
    """Padded [mb, B, d] batch tensors with n_real valid samples."""
    mb, bsz, d = spec.max_batches, spec.batch_size, spec.d
    x = np.zeros((mb, bsz, d), dtype=np.float32)
    y = np.zeros((mb, bsz), dtype=np.float32)
    mask = np.zeros((mb, bsz), dtype=np.float32)
    for i in range(n_real):
        b, s = divmod(i, bsz)
        x[b, s] = rng.standard_normal(d)
        if labels == "reg":
            y[b, s] = rng.standard_normal() * 5 + 20
        elif labels == "pm1":
            y[b, s] = 1.0 if rng.random() < 0.5 else -1.0
        else:
            y[b, s] = rng.integers(0, 10)
        mask[b, s] = 1.0
    return jnp.asarray(x), jnp.asarray(y), jnp.asarray(mask)


def test_regression_epoch_matches_numpy():
    spec = spec_by_name("regression")
    train_epoch, _ = build(spec)
    rng = np.random.default_rng(0)
    params = np.concatenate([rng.standard_normal(13) * 0.01, [0.0]]).astype(
        np.float32
    )
    x, y, mask = make_batches(rng, spec, n_real=23)
    got_params, got_loss = jax.jit(train_epoch)(jnp.asarray(params), x, y, mask)

    # Hand-rolled reference: batch-mean gradient SGD, same masking.
    p = params.copy()
    losses = []
    for b in range(spec.max_batches):
        valid = mask[b].sum()
        if valid == 0:
            continue
        xb = np.asarray(x[b])
        pred = xb @ p[:13] + p[13]
        err = (pred - np.asarray(y[b])) * np.asarray(mask[b])
        losses.append(0.5 * float((err**2).sum()) / float(valid))
        gw = xb.T @ err / float(valid)
        gb = err.sum() / float(valid)
        p[:13] -= spec.lr * gw
        p[13] -= spec.lr * gb
    np.testing.assert_allclose(got_params, p, rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(got_loss, np.mean(losses), rtol=1e-5)


def test_svm_epoch_matches_numpy():
    spec = spec_by_name("svm")
    train_epoch, _ = build(spec)
    rng = np.random.default_rng(1)
    d = spec.d
    params = np.concatenate([rng.standard_normal(d) * 0.01, [0.0]]).astype(
        np.float32
    )
    x, y, mask = make_batches(rng, spec, n_real=150, labels="pm1")
    got_params, _ = jax.jit(train_epoch)(jnp.asarray(params), x, y, mask)

    p = params.copy()
    for b in range(spec.max_batches):
        valid = float(mask[b].sum())
        if valid == 0:
            continue
        xb, yb, mb = np.asarray(x[b]), np.asarray(y[b]), np.asarray(mask[b])
        s = xb @ p[:d] + p[d]
        viol = ((yb * s < 1.0) & (mb > 0)).astype(np.float32)
        gw = -(xb * (yb * viol)[:, None]).sum(axis=0) / valid
        gb = -(yb * viol).sum() / valid
        p[:d] -= spec.lr * gw + spec.lr * SVM_L2 * p[:d]
        p[d] -= spec.lr * gb
    np.testing.assert_allclose(got_params, p, rtol=2e-4, atol=2e-5)


def test_masked_rows_contribute_nothing():
    """Padding rows must not change the update: compare a half-full
    epoch against the same data with extra garbage in masked slots."""
    for name in ["regression", "svm", "cnn"]:
        spec = spec_by_name(name)
        train_epoch, _ = build(spec)
        rng = np.random.default_rng(2)
        labels = {"regression": "reg", "svm": "pm1", "cnn": "cls"}[name]
        x, y, mask = make_batches(rng, spec, n_real=spec.batch_size + 1, labels=labels)
        dim = spec.param_dim
        params = jnp.asarray(rng.standard_normal(dim) * 0.01, dtype=jnp.float32)
        p1, l1 = jax.jit(train_epoch)(params, x, y, mask)
        # Poison the masked slots.
        x2 = np.asarray(x).copy()
        x2[np.asarray(mask) == 0] = 999.0
        p2, l2 = jax.jit(train_epoch)(params, jnp.asarray(x2), y, mask)
        np.testing.assert_allclose(p1, p2, rtol=1e-6, atol=1e-7, err_msg=name)
        np.testing.assert_allclose(l1, l2, rtol=1e-6, err_msg=name)


def test_cnn_epoch_reduces_loss():
    spec = spec_by_name("cnn")
    train_epoch, evaluate = build(spec)
    rng = np.random.default_rng(3)
    x, y, mask = make_batches(rng, spec, n_real=2 * spec.batch_size, labels="cls")
    params = jnp.asarray(
        np.concatenate(
            [rng.standard_normal(n) * std if std > 0 else np.zeros(n) for n, std in spec.init_blocks]
        ),
        dtype=jnp.float32,
    )
    step = jax.jit(train_epoch)
    p, loss0 = step(params, x, y, mask)
    for _ in range(4):
        p, loss = step(p, x, y, mask)
    assert float(loss) < float(loss0), f"{loss0} -> {loss}"


def test_eval_respects_sentinel_padding():
    spec = spec_by_name("regression")
    _, evaluate = build(spec)
    rng = np.random.default_rng(4)
    n = spec.n_test
    x = np.zeros((n, spec.d), dtype=np.float32)
    y = np.full((n,), MASK_SENTINEL, dtype=np.float32)
    n_real = 7
    x[:n_real] = rng.standard_normal((n_real, spec.d))
    y[:n_real] = rng.standard_normal(n_real) * 5 + 20
    params = jnp.asarray(rng.standard_normal(spec.param_dim) * 0.01)
    loss, acc = jax.jit(evaluate)(params, jnp.asarray(x), jnp.asarray(y))
    # Reference over the real rows only.
    pred = x[:n_real] @ np.asarray(params[:13]) + float(params[13])
    err = pred - y[:n_real]
    want_loss = 0.5 * float((err**2).mean())
    np.testing.assert_allclose(loss, want_loss, rtol=1e-4)
    assert 0.0 <= float(acc) <= 1.0


def test_specs_are_consistent():
    for paper in [False, True]:
        for spec in default_specs(paper=paper):
            assert spec.param_dim == sum(n for n, _ in spec.init_blocks)
            assert spec.batch_size > 0 and spec.max_batches > 0
            if spec.name == "cnn":
                flat = 4 * 4 * spec.c2
                expected = (
                    spec.c1 * 25 + spec.c1
                    + spec.c2 * 25 * spec.c1 + spec.c2
                    + flat * spec.hidden + spec.hidden
                    + spec.hidden * 10 + 10
                )
                assert spec.param_dim == expected
    # Paper CNN must match the architecture's parameter count.
    paper_cnn = next(s for s in default_specs(paper=True) if s.name == "cnn")
    assert paper_cnn.param_dim == 520 + 25_050 + 400_500 + 5_010
