"""L1 Pallas kernel: tiled fused linear layer ``act(x @ w + b)``.

This is the compute hot-spot of every SAFA local update: the dense matmul
inside the SGD step (the CNN's convolutions are im2col'd into it by the
L2 model, the TPU-standard adaptation — see DESIGN.md §Hardware-Adaptation).

TPU-shaped design:
  * BlockSpec tiles of (128, 128) on the M/N axes — MXU-aligned, and the
    per-step working set (x-tile + w-tile + out-tile) stays ~O(100 kB),
    far under the ~16 MB VMEM budget.
  * K is kept whole per tile (these models' K ≤ 800), so each grid step
    is a single MXU matmul with the bias add + activation fused into the
    epilogue — the output tile is written to HBM exactly once.
  * `interpret=True` everywhere: the CPU PJRT plugin cannot execute
    Mosaic custom-calls; interpret mode lowers to plain HLO, which is
    what the Rust runtime loads. Real-TPU efficiency is *estimated* in
    DESIGN.md §9 from the footprint above.

Autodiff: `pallas_call` has no VJP rule, so `fused_linear` is a
`jax.custom_vjp` whose backward pass reuses the same Pallas matmul kernel
for dx = g·wᵀ and dw = xᵀ·g.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-aligned tile sizes. On a real TPU (128, 128) output tiles are the
# natural MXU shape; under interpret-mode-on-CPU each grid step lowers to
# a full-output dynamic-update-slice, so small tiles make the loop
# copy-bound (measured 37 s for a 460k-row eval at BM=128 — see
# EXPERIMENTS.md §Perf). We therefore stretch the M tile up to 4096 rows
# (VMEM estimate stays ≤ 4096·K·4B ≈ 3.3 MB at K=200, far under 16 MB)
# and keep N at the MXU lane width.
BLOCK_M = 4096
BLOCK_N = 128


def _matmul_bias_act_kernel(x_ref, w_ref, b_ref, o_ref, *, act):
    """One (BM, BN) output tile: act(x_tile @ w_tile + b_tile)."""
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...][None, :]
    if act == "relu":
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def matmul_pallas(x, w, b=None, act="none"):
    """``act(x @ w + b)`` via the tiled Pallas kernel.

    x: [M, K], w: [K, N], b: [N] or None. Shapes are padded up to the
    block size and the result sliced back, so any M/N/K works.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims disagree: {k} vs {k2}"
    if b is None:
        b = jnp.zeros((n,), dtype=x.dtype)
    bm = min(BLOCK_M, max(m, 1))
    bn = min(BLOCK_N, max(n, 1))
    xp = _pad_to(x, bm, 0)
    wp = _pad_to(w, bn, 1)
    bp = _pad_to(b, bn, 0)
    mp, np_ = xp.shape[0], wp.shape[1]
    grid = (mp // bm, np_ // bn)
    out = pl.pallas_call(
        functools.partial(_matmul_bias_act_kernel, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def fused_linear(x, w, b, act="none"):
    """Differentiable fused linear layer backed by the Pallas kernel."""
    return matmul_pallas(x, w, b, act)


def _fused_linear_fwd(x, w, b, act):
    out = matmul_pallas(x, w, b, act)
    return out, (x, w, out)


def _fused_linear_bwd(act, res, g):
    x, w, out = res
    if act == "relu":
        g = g * (out > 0).astype(g.dtype)
    # All three cotangents flow through the same Pallas matmul kernel.
    dx = matmul_pallas(g, w.T)
    dw = matmul_pallas(x.T, g)
    db = jnp.sum(g, axis=0)
    return dx, dw, db


fused_linear.defvjp(_fused_linear_fwd, _fused_linear_bwd)
