"""Pure-jnp oracles for the Pallas kernels — the correctness reference.

Every kernel in this package must agree with its oracle here; pytest +
hypothesis sweep shapes/dtypes in python/tests/test_kernel.py. Keeping
the oracles dependency-free (no pallas import) means a kernel bug cannot
hide in shared code.
"""

import jax.numpy as jnp


def ref_linear(x, w, b=None, act="none"):
    """act(x @ w + b) in plain jnp."""
    out = jnp.dot(x, w, preferred_element_type=jnp.float32)
    if b is not None:
        out = out + b[None, :]
    if act == "relu":
        out = jnp.maximum(out, 0.0)
    elif act != "none":
        raise ValueError(f"unknown act {act!r}")
    return out


def ref_sgd(params, grads, lr):
    """params - lr * grads in plain jnp."""
    return params - lr * grads
