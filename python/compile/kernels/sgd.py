"""L1 Pallas kernel: fused SGD parameter update ``p - lr * g``.

Elementwise over the flat parameter vector, tiled in 1-D VMEM blocks.
Trivial compute, but keeping it in Pallas means the whole SGD step
(matmul + update) exercises the kernel path end to end, and on real TPU
the update fuses into a single HBM read-modify-write stream.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024


def _sgd_kernel(p_ref, g_ref, lr_ref, o_ref):
    o_ref[...] = p_ref[...] - lr_ref[0] * g_ref[...]


def sgd_update(params, grads, lr):
    """params - lr * grads via a tiled Pallas kernel (1-D f32 vectors)."""
    (n,) = params.shape
    block = min(BLOCK, max(n, 1))
    pad = (-n) % block
    pp = jnp.pad(params, (0, pad))
    gp = jnp.pad(grads, (0, pad))
    lr_arr = jnp.asarray([lr], dtype=jnp.float32)
    out = pl.pallas_call(
        _sgd_kernel,
        grid=(pp.shape[0] // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(pp.shape, jnp.float32),
        interpret=True,
    )(pp, gp, lr_arr)
    return out[:n]


@functools.partial(jax.jit, static_argnums=())
def sgd_update_jit(params, grads, lr):
    return sgd_update(params, grads, lr)
