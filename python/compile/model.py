"""L2: the paper's three task models in JAX, built on the L1 Pallas
kernels, with the exact math of the Rust native backend (losses, masking,
update rule and flat parameter layout) so the two backends agree
numerically on identical batches.

Each task exposes two jittable functions with static shapes:

* ``train_epoch(params, x, y, mask) -> (new_params, mean_loss)`` — one
  epoch of masked minibatch SGD. ``x`` is [max_batches, B, d]; padding
  rows carry mask 0 and contribute nothing; the Rust side loops E epochs
  and reshuffles between calls.
* ``evaluate(params, x, y) -> (loss, accuracy)`` — the paper's Table III
  accuracy for the task; padded rows are marked with y = MASK_SENTINEL.

Parameters are a single flat f32 vector; the layout (and its init
recipe) is published to the Rust runtime through the AOT manifest.
"""

from dataclasses import dataclass, field
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp

from compile.kernels.fused_linear import fused_linear, matmul_pallas
from compile.kernels.sgd import sgd_update

# Must match rust/src/runtime/mod.rs::MASK_SENTINEL.
MASK_SENTINEL = -1.0e9

SVM_L2 = 1e-4  # must match rust/src/model/native/linear.rs


@dataclass
class TaskSpec:
    """Static shapes + hyper-parameters one artifact is compiled for."""

    name: str
    d: int
    batch_size: int
    max_batches: int
    n_test: int
    lr: float
    # (len, std) parameter blocks — the manifest's init recipe.
    init_blocks: List[Tuple[int, float]] = field(default_factory=list)
    # CNN widths (ignored by the linear tasks).
    c1: int = 8
    c2: int = 16
    hidden: int = 64

    @property
    def param_dim(self) -> int:
        return sum(n for n, _ in self.init_blocks)


# ---------------------------------------------------------------------------
# Linear models (Task 1 regression / Task 3 SVM). Params: [w(d), b].
# ---------------------------------------------------------------------------


def _linear_scores(params, x, d):
    """x @ w + b for a batch via the Pallas kernel. x: [B, d]."""
    w = params[:d].reshape(d, 1)
    b = params[d : d + 1]
    return fused_linear(x, w, b, "none")[:, 0]


def make_regression(spec: TaskSpec):
    d = spec.d

    def batch_step(params, batch):
        x, y, mask = batch  # [B, d], [B], [B]
        valid = jnp.maximum(jnp.sum(mask), 1.0)

        def loss_fn(p):
            pred = _linear_scores(p, x, d)
            err = pred - y
            # 0.5 * mean(err^2) over valid rows (rust: loss/bsz).
            return 0.5 * jnp.sum(err * err * mask) / valid

        loss, grads = jax.value_and_grad(loss_fn)(params)
        has_valid = (jnp.sum(mask) > 0).astype(jnp.float32)
        new_params = sgd_update(params, grads * has_valid, spec.lr)
        return new_params, (loss, has_valid)

    def train_epoch(params, x, y, mask):
        params, (losses, valids) = jax.lax.scan(
            batch_step, params, (x, y, mask)
        )
        denom = jnp.maximum(jnp.sum(valids), 1.0)
        return params, jnp.sum(losses * valids) / denom

    def evaluate(params, x, y):
        valid = (y > MASK_SENTINEL / 2).astype(jnp.float32)
        n = jnp.maximum(jnp.sum(valid), 1.0)
        pred = _linear_scores(params, x, d)
        err = pred - y
        loss = 0.5 * jnp.sum(err * err * valid) / n
        # Table III row 1: acc = 1 - mean(|y - yhat| / max(y, yhat)).
        denom = jnp.maximum(jnp.maximum(y, pred), 1e-6)
        rel = jnp.minimum(jnp.abs(y - pred) / denom, 1.0)
        acc = jnp.sum((1.0 - rel) * valid) / n
        return loss, acc

    return train_epoch, evaluate


def make_svm(spec: TaskSpec):
    d = spec.d

    def batch_step(params, batch):
        x, y, mask = batch
        valid = jnp.maximum(jnp.sum(mask), 1.0)

        def loss_fn(p):
            s = _linear_scores(p, x, d)
            hinge = jnp.maximum(0.0, 1.0 - y * s) * mask
            w = p[:d]
            # rust: (sum hinge + 0.5*l2*|w|^2) / bsz for the reported
            # loss; the l2 *gradient* is applied un-normalized
            # (w -= lr*l2*w), so split the two like the rust code does.
            return jnp.sum(hinge) / valid

        loss, grads = jax.value_and_grad(loss_fn)(params)
        w = params[:d]
        reg_loss = 0.5 * SVM_L2 * jnp.sum(w * w) / valid
        # L2 gradient applied per batch exactly like rust:
        # w -= lr*(hinge_grad) + lr*SVM_L2*w.
        reg_grad = jnp.concatenate([SVM_L2 * w, jnp.zeros((1,))])
        has_valid = (jnp.sum(mask) > 0).astype(jnp.float32)
        total_grad = (grads + reg_grad) * has_valid
        new_params = sgd_update(params, total_grad, spec.lr)
        return new_params, (loss + reg_loss, has_valid)

    def train_epoch(params, x, y, mask):
        params, (losses, valids) = jax.lax.scan(
            batch_step, params, (x, y, mask)
        )
        denom = jnp.maximum(jnp.sum(valids), 1.0)
        return params, jnp.sum(losses * valids) / denom

    def evaluate(params, x, y):
        valid = (y > MASK_SENTINEL / 2).astype(jnp.float32)
        n = jnp.maximum(jnp.sum(valid), 1.0)
        s = _linear_scores(params, x, d)
        loss = jnp.sum(jnp.maximum(0.0, 1.0 - y * s) * valid) / n
        acc = jnp.sum((y * s > 0).astype(jnp.float32) * valid) / n
        return loss, acc

    return train_epoch, evaluate


# ---------------------------------------------------------------------------
# CNN (Task 2). Layout matches rust/src/model/native/cnn.rs:
# [W1(c1,25), b1, W2(c2,25*c1), b2, Wh(flat,hidden), bh, Wo(hidden,10), bo]
# channels-last activations, im2col patches ordered (ky, kx, c).
# ---------------------------------------------------------------------------

SIDE = 28
K = 5
H1 = SIDE - K + 1  # 24
P1 = H1 // 2  # 12
H2 = P1 - K + 1  # 8
P2 = H2 // 2  # 4
CLASSES = 10


def _im2col(x, oh, ow):
    """[B, H, W, C] -> [B, OH, OW, K*K*C] with (ky, kx, c) patch order —
    identical to the Rust im2col_nhwc layout."""
    patches = [
        x[:, ky : ky + oh, kx : kx + ow, :] for ky in range(K) for kx in range(K)
    ]
    return jnp.concatenate(patches, axis=-1)


def _maxpool2(x):
    """2x2/2 max pool, channels-last."""
    b, h, w, c = x.shape
    x = x.reshape(b, h // 2, 2, w // 2, 2, c)
    return jnp.max(x, axis=(2, 4))


def _cnn_unpack(params, spec):
    c1, c2, hidden = spec.c1, spec.c2, spec.hidden
    flat = P2 * P2 * c2
    sizes = [c1 * K * K, c1, c2 * K * K * c1, c2, flat * hidden, hidden,
             hidden * CLASSES, CLASSES]
    offs = [0]
    for s in sizes:
        offs.append(offs[-1] + s)
    w1 = params[offs[0] : offs[1]].reshape(c1, K * K)
    b1 = params[offs[1] : offs[2]]
    w2 = params[offs[2] : offs[3]].reshape(c2, K * K * c1)
    b2 = params[offs[3] : offs[4]]
    wh = params[offs[4] : offs[5]].reshape(flat, hidden)
    bh = params[offs[5] : offs[6]]
    wo = params[offs[6] : offs[7]].reshape(hidden, CLASSES)
    bo = params[offs[7] : offs[8]]
    return w1, b1, w2, b2, wh, bh, wo, bo


def _cnn_logits(params, x, spec):
    """Forward pass; x: [B, 784] -> logits [B, 10]. Every matmul runs
    through the Pallas fused_linear kernel."""
    b = x.shape[0]
    w1, b1, w2, b2, wh, bh, wo, bo = _cnn_unpack(params, spec)
    img = x.reshape(b, SIDE, SIDE, 1)
    cols1 = _im2col(img, H1, H1).reshape(b * H1 * H1, K * K)
    a1 = fused_linear(cols1, w1.T, b1, "relu").reshape(b, H1, H1, spec.c1)
    p1 = _maxpool2(a1)
    cols2 = _im2col(p1, H2, H2).reshape(b * H2 * H2, K * K * spec.c1)
    a2 = fused_linear(cols2, w2.T, b2, "relu").reshape(b, H2, H2, spec.c2)
    p2 = _maxpool2(a2).reshape(b, P2 * P2 * spec.c2)
    ah = fused_linear(p2, wh, bh, "relu")
    return fused_linear(ah, wo, bo, "none")


def make_cnn(spec: TaskSpec):
    def batch_step(params, batch):
        x, y, mask = batch
        valid = jnp.maximum(jnp.sum(mask), 1.0)

        def loss_fn(p):
            logits = _cnn_logits(p, x, spec)
            logp = jax.nn.log_softmax(logits, axis=-1)
            labels = jnp.clip(y.astype(jnp.int32), 0, CLASSES - 1)
            nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
            return jnp.sum(nll * mask) / valid

        loss, grads = jax.value_and_grad(loss_fn)(params)
        has_valid = (jnp.sum(mask) > 0).astype(jnp.float32)
        new_params = sgd_update(params, grads * has_valid, spec.lr)
        return new_params, (loss, has_valid)

    def train_epoch(params, x, y, mask):
        params, (losses, valids) = jax.lax.scan(
            batch_step, params, (x, y, mask)
        )
        denom = jnp.maximum(jnp.sum(valids), 1.0)
        return params, jnp.sum(losses * valids) / denom

    def evaluate(params, x, y):
        valid = (y > MASK_SENTINEL / 2).astype(jnp.float32)
        n = jnp.maximum(jnp.sum(valid), 1.0)
        logits = _cnn_logits(params, x, spec)
        logp = jax.nn.log_softmax(logits, axis=-1)
        labels = jnp.clip(y.astype(jnp.int32), 0, CLASSES - 1)
        nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
        loss = jnp.sum(nll * valid) / n
        correct = (jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32)
        acc = jnp.sum(correct * valid) / n
        return loss, acc

    return train_epoch, evaluate


# ---------------------------------------------------------------------------
# Task registry: shapes sized for the scaled presets the Rust side runs on
# this box (paper-sized shapes are a flag away; see aot.py --paper).
# ---------------------------------------------------------------------------


def he(n: int, fan_in: int) -> Tuple[int, float]:
    return (n, (2.0 / fan_in) ** 0.5)


def cnn_blocks(c1, c2, hidden):
    flat = P2 * P2 * c2
    return [
        he(c1 * K * K, K * K),
        (c1, 0.0),
        he(c2 * K * K * c1, K * K * c1),
        (c2, 0.0),
        he(flat * hidden, flat),
        (hidden, 0.0),
        he(hidden * CLASSES, hidden),
        (CLASSES, 0.0),
    ]


def default_specs(paper: bool = False) -> List[TaskSpec]:
    """Artifact shape table. Must stay in sync with the Rust presets
    (config/presets.rs): batch size, lr and d are validated at load time
    by the Rust runtime."""
    if paper:
        cnn = dict(c1=20, c2=50, hidden=500)
        cnn_mb, cnn_ntest = 32, 10_000
        svm_mb, svm_ntest = 8, 20_000
        reg_mb = 64
    else:
        cnn = dict(c1=8, c2=16, hidden=64)
        cnn_mb, cnn_ntest = 4, 800
        svm_mb, svm_ntest = 4, 4_000
        reg_mb = 64
    return [
        TaskSpec(
            name="regression",
            d=13,
            batch_size=5,
            max_batches=reg_mb,
            n_test=100,
            lr=2e-3,
            init_blocks=[(13, 0.01), (1, 0.0)],
        ),
        TaskSpec(
            name="cnn",
            d=SIDE * SIDE,
            batch_size=40,
            max_batches=cnn_mb,
            n_test=cnn_ntest,
            lr=1e-3,
            init_blocks=cnn_blocks(cnn["c1"], cnn["c2"], cnn["hidden"]),
            **cnn,
        ),
        TaskSpec(
            name="svm",
            d=35,
            batch_size=100,
            max_batches=svm_mb,
            n_test=svm_ntest,
            lr=1e-2,
            init_blocks=[(35, 0.01), (1, 0.0)],
        ),
    ]


def build(spec: TaskSpec) -> Tuple[Callable, Callable]:
    """(train_epoch, evaluate) for a task spec."""
    if spec.name == "regression":
        return make_regression(spec)
    if spec.name == "svm":
        return make_svm(spec)
    if spec.name == "cnn":
        return make_cnn(spec)
    raise ValueError(f"unknown task {spec.name!r}")
