"""AOT pipeline: lower the L2 JAX models (with their L1 Pallas kernels)
to HLO **text** and emit the manifest the Rust runtime consumes.

Run once at build time (`make artifacts`); Python never executes on the
experiment path. HLO text — not serialized protos — is the interchange
format: the Rust side's xla_extension 0.5.1 rejects jax>=0.5's 64-bit
instruction ids, while the text parser reassigns ids cleanly (see
/opt/xla-example/README.md).

Usage: python -m compile.aot [--out-dir ../artifacts] [--paper]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile.model import TaskSpec, build, default_specs


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side unwraps one tuple regardless of arity)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_task(spec: TaskSpec, out_dir: str) -> dict:
    """Lower train + eval graphs for one task; return its manifest entry."""
    train_epoch, evaluate = build(spec)
    f32 = jnp.float32
    p = jax.ShapeDtypeStruct((spec.param_dim,), f32)
    x = jax.ShapeDtypeStruct((spec.max_batches, spec.batch_size, spec.d), f32)
    y = jax.ShapeDtypeStruct((spec.max_batches, spec.batch_size), f32)
    mask = jax.ShapeDtypeStruct((spec.max_batches, spec.batch_size), f32)
    train_hlo = f"{spec.name}_train.hlo.txt"
    with open(os.path.join(out_dir, train_hlo), "w") as f:
        f.write(to_hlo_text(jax.jit(train_epoch).lower(p, x, y, mask)))

    ex = jax.ShapeDtypeStruct((spec.n_test, spec.d), f32)
    ey = jax.ShapeDtypeStruct((spec.n_test,), f32)
    eval_hlo = f"{spec.name}_eval.hlo.txt"
    with open(os.path.join(out_dir, eval_hlo), "w") as f:
        f.write(to_hlo_text(jax.jit(evaluate).lower(p, ex, ey)))

    return {
        "train_hlo": train_hlo,
        "eval_hlo": eval_hlo,
        "param_dim": spec.param_dim,
        "d": spec.d,
        "batch_size": spec.batch_size,
        "max_batches": spec.max_batches,
        "n_test": spec.n_test,
        "lr": spec.lr,
        "init": [{"len": n, "std": std} for n, std in spec.init_blocks],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--paper",
        action="store_true",
        help="paper-sized shapes (Table II) instead of the scaled presets",
    )
    ap.add_argument(
        "--tasks",
        default="regression,cnn,svm",
        help="comma-separated subset to lower",
    )
    # Back-compat with the original Makefile target.
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()
    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    os.makedirs(out_dir, exist_ok=True)

    wanted = {t.strip() for t in args.tasks.split(",")}
    manifest = {"tasks": {}}
    for spec in default_specs(paper=args.paper):
        if spec.name not in wanted:
            continue
        print(f"lowering {spec.name} (param_dim={spec.param_dim}) ...")
        manifest["tasks"][spec.name] = lower_task(spec, out_dir)
    path = os.path.join(out_dir, "manifest.json")
    with open(path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {path} with {len(manifest['tasks'])} task(s)")


if __name__ == "__main__":
    main()
